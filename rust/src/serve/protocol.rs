//! The JSONL serve protocol (RFC `docs/rfcs/0002-serve-protocol.md`,
//! v2) and the stdin/TCP drivers of `efqat serve`.
//!
//! One request per line in, one response per line out:
//!
//! ```text
//! → {"id": "r1", "model": "mlp", "data": [0.1, -0.4, ...]}
//! ← {"id":"r1","model":"mlp","fp":"9c1e64a2b0f3","gen":1,"shape":[10],"logits":[1.52,...]}
//! → {"id": 7, "model": "nope", "data": [3, 1, 4], "shape": [3]}
//! ← {"id":7,"code":"unknown_model","error":"unknown model \"nope\"; serving: [mlp]"}
//! → {"id": 8, "stats": true}
//! ← {"id":8,"models":[{"model":"mlp","fp":"9c1e64a2...","gen":1,"queued":0,...}]}
//! ```
//!
//! v2 adds model routing over v1: requests name a `model` (absent =
//! the registry's default model, which is how v1 clients keep working),
//! responses echo which engine answered (`model`, `fp` fingerprint
//! prefix, `gen` generation — the hot-swap observability surface), and
//! errors carry a stable machine-readable `code`
//! ([`crate::serve::SubmitError::code`] plus `bad_request`/`failed`).
//!
//! Responses are written in request order (FIFO): the reader thread
//! submits each parsed line to the [`Server`] and hands the ticket to a
//! writer thread that resolves them in submission order.  Head-of-line
//! waiting is bounded by the batcher deadline, and FIFO output means a
//! client can correlate by position as well as by `id`.

#![warn(missing_docs)]

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;

use crate::backend::Value;
use crate::error::{anyhow, Context, Result};
use crate::graph::InputKind;
use crate::json::Json;
use crate::tensor::{ITensor, Tensor};

use super::queue::BoundedQueue;
use super::registry::{ModelStats, Registry, Reply, SubmitError};
use super::{Server, Ticket};

/// The newest protocol version this build speaks.  Requests may pin a
/// version with the optional `"v"` field; absent means newest.
pub const PROTOCOL_VERSION: u64 = 2;

/// The oldest protocol version still accepted (v1: model-less requests,
/// answered by the registry's default model).
pub const MIN_PROTOCOL_VERSION: u64 = 1;

/// A wire-level rejection: a stable machine-readable `code` (clients
/// react mechanically — back off on `overloaded`, re-resolve on
/// `unknown_model`) plus the human-readable message.
#[derive(Debug)]
pub struct WireError {
    /// Stable error code (`bad_request`, `failed`, or a
    /// [`SubmitError::code`]).
    pub code: &'static str,
    /// Human-readable detail for the `error` field.
    pub msg: String,
}

impl WireError {
    fn bad(msg: impl Into<String>) -> WireError {
        WireError { code: "bad_request", msg: msg.into() }
    }
}

impl From<SubmitError> for WireError {
    fn from(e: SubmitError) -> WireError {
        WireError { code: e.code(), msg: e.to_string() }
    }
}

/// A successfully parsed request line.
pub enum Parsed {
    /// An inference request: route `input` to `model` (or the default).
    Infer {
        /// The `"model"` field, if present (v2).
        model: Option<String>,
        /// The decoded example, validated against the routed engine's
        /// input domain.
        input: Value,
    },
    /// A `{"stats": true}` introspection request (v2): answer inline
    /// with per-model counters, nothing enters a batch.
    Stats,
}

/// Parse one request line against the registry.  Returns the request id
/// (for the response envelope — `Json::Null` when the line is too
/// broken to carry one) alongside the parsed request or the typed error
/// to answer with.
pub fn parse_request(line: &str, registry: &Registry) -> (Json, Result<Parsed, WireError>) {
    let doc = match Json::parse(line) {
        Ok(d) => d,
        Err(e) => return (Json::Null, Err(WireError::bad(format!("bad request JSON: {e}")))),
    };
    let id = doc.opt("id").cloned().unwrap_or(Json::Null);
    (id, decode_request(&doc, registry))
}

fn decode_request(doc: &Json, registry: &Registry) -> Result<Parsed, WireError> {
    if doc.opt("id").is_none() {
        return Err(WireError::bad("request is missing the required \"id\" field"));
    }
    // version negotiation: absent "v" means newest; v1 is the legacy
    // model-less grammar, so v2-only fields are rejected under it
    // rather than silently ignored (a v1 client naming a model would
    // otherwise get the default model's logits)
    let version = match doc.opt("v") {
        Some(v) => {
            let v =
                v.num().map_err(|e| WireError::bad(format!("request \"v\" field: {e}")))? as u64;
            if !(MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&v) {
                return Err(WireError::bad(format!(
                    "unsupported protocol version {v} (this build speaks \
                     v{MIN_PROTOCOL_VERSION}..=v{PROTOCOL_VERSION})"
                )));
            }
            v
        }
        None => PROTOCOL_VERSION,
    };
    let model = match doc.opt("model") {
        Some(m) => {
            if version < 2 {
                return Err(WireError::bad("the \"model\" field requires protocol v2"));
            }
            Some(
                m.str()
                    .map_err(|e| WireError::bad(format!("request \"model\" field: {e}")))?
                    .to_string(),
            )
        }
        None => None,
    };
    if let Some(s) = doc.opt("stats") {
        if version < 2 {
            return Err(WireError::bad("the \"stats\" field requires protocol v2"));
        }
        return match s {
            Json::Bool(true) => Ok(Parsed::Stats),
            _ => Err(WireError::bad("request \"stats\" field must be `true`")),
        };
    }
    // decode the payload against the engine the request routes to; a
    // concurrent hot swap cannot invalidate this (swaps preserve the
    // input geometry — see the registry's install contract)
    let engine = registry.engine_for(model.as_deref()).map_err(WireError::from)?.engine;
    let data = doc
        .opt("data")
        .ok_or_else(|| WireError::bad("request is missing the required \"data\" field"))?
        .arr()
        .map_err(|e| WireError::bad(format!("request \"data\" field: {e}")))?;
    let shape = match doc.opt("shape") {
        Some(s) => s.shape().map_err(|e| WireError::bad(format!("request \"shape\" field: {e}")))?,
        None => engine.example_shape(),
    };
    let want: usize = shape.iter().product();
    if data.len() != want {
        return Err(WireError::bad(format!(
            "request \"data\" has {} elements, shape {shape:?} wants {want}",
            data.len()
        )));
    }
    let input = match engine.input() {
        InputKind::Image { .. } => {
            let data: Result<Vec<f32>> = data.iter().map(|j| j.num().map(|n| n as f32)).collect();
            Value::F32(Tensor { shape, data: data.map_err(|e| WireError::bad(e.to_string()))? })
        }
        InputKind::Tokens { .. } => {
            // token ids must arrive as integers — silently truncating 5.9
            // to token 5 would serve a sequence the client never sent
            let data: Result<Vec<i32>> = data
                .iter()
                .map(|j| {
                    let n = j.num()?;
                    if n.fract() != 0.0 || !(i32::MIN as f64..=i32::MAX as f64).contains(&n) {
                        return Err(anyhow!("token id {n} is not an integer id"));
                    }
                    Ok(n as i32)
                })
                .collect();
            Value::I32(ITensor { shape, data: data.map_err(|e| WireError::bad(e.to_string()))? })
        }
    };
    Ok(Parsed::Infer { model, input })
}

/// Abbreviate a fingerprint for per-reply envelopes (12 hex chars
/// disambiguate among any sane number of checkpoints; stats carry the
/// full digest).
fn fp_prefix(fp: &str) -> &str {
    fp.get(..12).unwrap_or(fp)
}

/// Render one successful response line (no trailing newline): the
/// logits plus the identity of the engine that computed them.  Always
/// single-line ([`Json::render_min`]).
pub fn render_reply(id: &Json, r: &Reply) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("id".to_string(), id.clone());
    obj.insert("model".to_string(), Json::Str(r.model.to_string()));
    obj.insert("fp".to_string(), Json::Str(fp_prefix(&r.fingerprint).to_string()));
    obj.insert("gen".to_string(), Json::Num(r.generation as f64));
    obj.insert(
        "shape".to_string(),
        Json::Arr(r.logits.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
    );
    obj.insert(
        "logits".to_string(),
        Json::Arr(r.logits.data.iter().map(|&v| Json::Num(v as f64)).collect()),
    );
    Json::Obj(obj).render_min()
}

/// Render one error response line: the stable `code` plus the message.
pub fn render_error(id: &Json, code: &str, msg: &str) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("id".to_string(), id.clone());
    obj.insert("code".to_string(), Json::Str(code.to_string()));
    obj.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(obj).render_min()
}

/// Render one stats response line: per-model queue depth, capacity,
/// active fingerprint (full digest) and generation, draining flag —
/// plus, once a lane has served traffic, its live trace surface (RFC
/// 0006): event count, EWMA batch-fill ratio, and per-stage
/// `queue_us`/`batch_us`/`exec_us`/`total_us` p50/p95/p99 objects.
/// The additions are additive within protocol v2 (readers ignore
/// unknown fields).
pub fn render_stats(id: &Json, stats: &[ModelStats]) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("id".to_string(), id.clone());
    obj.insert(
        "models".to_string(),
        Json::Arr(
            stats
                .iter()
                .map(|s| {
                    let mut m = BTreeMap::new();
                    m.insert("model".to_string(), Json::Str(s.model.clone()));
                    m.insert("fp".to_string(), Json::Str(s.fingerprint.clone()));
                    m.insert("gen".to_string(), Json::Num(s.generation as f64));
                    m.insert("queued".to_string(), Json::Num(s.queued as f64));
                    m.insert("cap".to_string(), Json::Num(s.capacity as f64));
                    m.insert("draining".to_string(), Json::Bool(s.draining));
                    if let Some(t) = &s.trace {
                        m.insert("events".to_string(), Json::Num(t.events as f64));
                        m.insert("batch_fill".to_string(), Json::Num(s.batch_fill));
                        m.insert("queue_us".to_string(), stage_obj(&t.queue));
                        m.insert("batch_us".to_string(), stage_obj(&t.batch));
                        m.insert("exec_us".to_string(), stage_obj(&t.exec));
                        m.insert("total_us".to_string(), stage_obj(&t.total));
                    }
                    Json::Obj(m)
                })
                .collect(),
        ),
    );
    Json::Obj(obj).render_min()
}

fn stage_obj(p: &crate::serve::trace::StagePcts) -> Json {
    let mut o = BTreeMap::new();
    o.insert("p50".to_string(), Json::Num(p.p50_us));
    o.insert("p95".to_string(), Json::Num(p.p95_us));
    o.insert("p99".to_string(), Json::Num(p.p99_us));
    Json::Obj(o)
}

/// What the in-order writer resolves for one request line.
enum Pending {
    /// An accepted inference request; wait for its reply.
    Ticket(Ticket),
    /// Rejected before entering a batch; answer with the typed code.
    Failed(WireError),
    /// Already rendered inline (stats) — FIFO position preserved.
    Rendered(String),
}

/// Drive the server over one line stream: read → submit → answer, with
/// responses written in request order.  Returns the number of lines
/// answered once the input reaches EOF and every ticket resolved.
pub fn serve_stream<R: BufRead, W: Write + Send>(
    server: &Server,
    input: R,
    mut output: W,
) -> Result<usize> {
    // tickets ride a second bounded queue so reading (and batching)
    // stays ahead of the in-order writer
    let tickets: std::sync::Arc<BoundedQueue<(Json, Pending)>> = BoundedQueue::new(4096);
    std::thread::scope(|s| -> Result<usize> {
        let writer_tickets = tickets.clone();
        let writer = s.spawn(move || -> Result<usize> {
            let mut served = 0usize;
            while let Some((id, pending)) = writer_tickets.pop() {
                let line = match pending {
                    Pending::Ticket(t) => match t.wait_reply() {
                        Ok(reply) => render_reply(&id, &reply),
                        Err(e) => render_error(&id, "failed", &e.to_string()),
                    },
                    Pending::Failed(we) => render_error(&id, we.code, &we.msg),
                    Pending::Rendered(line) => line,
                };
                let wrote = writeln!(output, "{line}").and_then(|()| output.flush());
                if let Err(e) = wrote {
                    // the reader may be blocked pushing into a full
                    // tickets queue; closing it unblocks the reader so
                    // serve_stream returns instead of wedging (e.g. on
                    // EPIPE when the consumer of stdout went away)
                    writer_tickets.close();
                    return Err(anyhow!("writing response: {e}"));
                }
                served += 1;
            }
            Ok(served)
        });
        for line in input.lines() {
            let line = match line {
                Ok(l) => l,
                Err(e) => {
                    tickets.close();
                    let _ = writer.join();
                    return Err(anyhow!("reading request line: {e}"));
                }
            };
            if line.trim().is_empty() {
                continue;
            }
            let (id, parsed) = parse_request(&line, server.registry());
            let pending = match parsed {
                Ok(Parsed::Infer { model, input }) => {
                    match server.try_submit(model.as_deref(), input) {
                        Ok(t) => Pending::Ticket(t),
                        Err(e) => Pending::Failed(e.into()),
                    }
                }
                Ok(Parsed::Stats) => Pending::Rendered(render_stats(&id, &server.stats())),
                Err(we) => Pending::Failed(we),
            };
            if tickets.push((id, pending)).is_err() {
                break; // writer side is gone
            }
        }
        tickets.close();
        writer.join().map_err(|_| anyhow!("response writer panicked"))?
    })
}

/// Serve JSONL over TCP: accept connections forever on
/// `{bind}:{port}`, one reader/writer pair per connection, all feeding
/// the same per-model batchers — concurrent clients get co-batched.
/// Per-connection failures are logged and do not stop the listener;
/// this returns only if the listener socket itself fails.
pub fn serve_tcp(server: &Server, bind: &str, port: u16) -> Result<()> {
    let listener =
        TcpListener::bind((bind, port)).with_context(|| format!("binding {bind}:{port}"))?;
    eprintln!("[serve] listening on {bind}:{port} (JSONL per connection)");
    std::thread::scope(|s| {
        for conn in listener.incoming() {
            match conn {
                Ok(stream) => {
                    s.spawn(move || {
                        let peer = stream
                            .peer_addr()
                            .map(|a| a.to_string())
                            .unwrap_or_else(|_| "?".into());
                        let reader = match stream.try_clone() {
                            Ok(r) => BufReader::new(r),
                            Err(e) => {
                                eprintln!("[serve] {peer}: {e}");
                                return;
                            }
                        };
                        match serve_stream(server, reader, &stream) {
                            Ok(n) => eprintln!("[serve] {peer}: answered {n} requests"),
                            Err(e) => eprintln!("[serve] {peer}: {e}"),
                        }
                    });
                }
                Err(e) => eprintln!("[serve] accept failed: {e}"),
            }
        }
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn registry_with(models: &[&str]) -> Registry {
        let reg = Registry::new();
        for m in models {
            let eng: Arc<dyn super::super::Engine> =
                Arc::new(crate::serve::test_fixture::lowered(m));
            reg.install(m, eng, &format!("fp-{m}")).unwrap();
        }
        reg
    }

    fn unwrap_infer(p: Result<Parsed, WireError>) -> (Option<String>, Value) {
        match p {
            Ok(Parsed::Infer { model, input }) => (model, input),
            Ok(Parsed::Stats) => panic!("want Infer, got Stats"),
            Err(e) => panic!("want Infer, got [{}] {}", e.code, e.msg),
        }
    }

    #[test]
    fn parse_accepts_default_and_explicit_shape() {
        let reg = registry_with(&["mlp"]);
        let data: Vec<String> = (0..192).map(|i| format!("{}", i as f32 * 0.01)).collect();
        let line = format!("{{\"id\": \"a\", \"data\": [{}]}}", data.join(","));
        let (id, p) = parse_request(&line, &reg);
        assert_eq!(id, Json::Str("a".into()));
        let (model, input) = unwrap_infer(p);
        assert_eq!(model, None);
        assert_eq!(input.shape(), &[3, 8, 8]);

        let body = data.join(",");
        let line = format!("{{\"id\": 2, \"v\": 1, \"shape\": [3, 8, 8], \"data\": [{body}]}}");
        let (id, p) = parse_request(&line, &reg);
        assert_eq!(id, Json::Num(2.0));
        unwrap_infer(p);
    }

    #[test]
    fn parse_routes_v2_model_field() {
        let reg = registry_with(&["mlp", "convnet"]);
        let data: Vec<String> = (0..192).map(|i| format!("{}", i as f32 * 0.01)).collect();
        let body = data.join(",");
        let line = format!("{{\"id\": 1, \"v\": 2, \"model\": \"convnet\", \"data\": [{body}]}}");
        let (model, _) = unwrap_infer(parse_request(&line, &reg).1);
        assert_eq!(model.as_deref(), Some("convnet"));
        // absent "v" means newest: model routing works without pinning
        let line = format!("{{\"id\": 1, \"model\": \"mlp\", \"data\": [{body}]}}");
        let (model, _) = unwrap_infer(parse_request(&line, &reg).1);
        assert_eq!(model.as_deref(), Some("mlp"));
    }

    #[test]
    fn parse_rejects_bad_requests_with_typed_codes() {
        let reg = registry_with(&["mlp"]);
        // broken JSON: no id recoverable
        let (id, p) = parse_request("{nope", &reg);
        assert_eq!(id, Json::Null);
        let e = p.err().unwrap();
        assert_eq!(e.code, "bad_request");
        assert!(e.msg.contains("bad request JSON"), "{}", e.msg);
        // well-formed but wrong element count: id still echoed
        let (id, p) = parse_request(r#"{"id": "x", "data": [1, 2]}"#, &reg);
        assert_eq!(id, Json::Str("x".into()));
        assert!(p.err().unwrap().msg.contains("2 elements"));
        // missing id
        let (_, p) = parse_request(r#"{"data": [1]}"#, &reg);
        assert!(p.err().unwrap().msg.contains("\"id\""));
        // future protocol version
        let (_, p) = parse_request(r#"{"id": 1, "v": 3, "data": [1]}"#, &reg);
        assert!(p.err().unwrap().msg.contains("protocol version"));
        // v1 requests cannot name a model: that grammar is v2-only
        let (_, p) = parse_request(r#"{"id": 1, "v": 1, "model": "mlp", "data": [1]}"#, &reg);
        let e = p.err().unwrap();
        assert_eq!(e.code, "bad_request");
        assert!(e.msg.contains("requires protocol v2"), "{}", e.msg);
        // unknown model: the registry's typed code passes through
        let (_, p) = parse_request(r#"{"id": 1, "model": "ghost", "data": [1]}"#, &reg);
        assert_eq!(p.err().unwrap().code, "unknown_model");
    }

    #[test]
    fn stats_requests_parse_and_render() {
        let reg = registry_with(&["mlp"]);
        let (_, p) = parse_request(r#"{"id": 5, "stats": true}"#, &reg);
        assert!(matches!(p, Ok(Parsed::Stats)));
        let (_, p) = parse_request(r#"{"id": 5, "v": 1, "stats": true}"#, &reg);
        assert!(p.err().unwrap().msg.contains("requires protocol v2"));
        let line = render_stats(&Json::Num(5.0), &reg.stats());
        let doc = Json::parse(&line).unwrap();
        let models = doc.get("models").unwrap().arr().unwrap();
        assert_eq!(models.len(), 1);
        assert_eq!(models[0].get("model").unwrap().str().unwrap(), "mlp");
        assert_eq!(models[0].get("fp").unwrap().str().unwrap(), "fp-mlp");
    }

    #[test]
    fn token_requests_reject_non_integer_ids() {
        let reg = registry_with(&["tiny_tf"]);
        let ids: Vec<String> = (0..16).map(|i| (i % 64).to_string()).collect();
        let line = format!("{{\"id\": 1, \"data\": [{}]}}", ids.join(","));
        let (_, p) = parse_request(&line, &reg);
        assert!(p.is_ok());
        // 5.9 must not silently truncate to token 5
        let mut ids = ids;
        ids[3] = "5.9".to_string();
        let line = format!("{{\"id\": 1, \"data\": [{}]}}", ids.join(","));
        let (_, p) = parse_request(&line, &reg);
        assert!(p.err().unwrap().msg.contains("not an integer"), "float id accepted");
    }

    #[test]
    fn response_lines_round_trip() {
        let id = Json::Str("r9".into());
        let reply = Reply {
            logits: Tensor { shape: vec![2], data: vec![1.5, -0.25] },
            model: Arc::from("mlp"),
            fingerprint: Arc::from("0123456789abcdef0123"),
            generation: 3,
        };
        let line = render_reply(&id, &reply);
        let doc = Json::parse(&line).unwrap();
        assert_eq!(doc.get("id").unwrap(), &id);
        assert_eq!(doc.get("model").unwrap().str().unwrap(), "mlp");
        assert_eq!(doc.get("fp").unwrap().str().unwrap(), "0123456789ab");
        assert_eq!(doc.get("gen").unwrap().num().unwrap() as u64, 3);
        assert_eq!(doc.get("shape").unwrap().shape().unwrap(), vec![2]);
        let logits = doc.get("logits").unwrap().arr().unwrap();
        assert_eq!(logits[1].num().unwrap() as f32, -0.25);

        let doc = Json::parse(&render_error(&id, "overloaded", "boom")).unwrap();
        assert_eq!(doc.get("code").unwrap().str().unwrap(), "overloaded");
        assert_eq!(doc.get("error").unwrap().str().unwrap(), "boom");
    }
}
