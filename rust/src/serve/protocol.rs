//! The JSONL serve protocol (RFC `docs/rfcs/0002-serve-protocol.md`) and
//! the stdin/TCP drivers of `efqat serve`.
//!
//! One request per line in, one response per line out:
//!
//! ```text
//! → {"id": "r1", "data": [0.1, -0.4, ...]}
//! ← {"id":"r1","shape":[10],"logits":[1.52,...]}
//! → {"id": 7, "data": [3, 1, 4], "shape": [3]}
//! ← {"id":7,"error":"mlp: want an f32 example of shape [3, 8, 8], got [3]"}
//! ```
//!
//! Responses are written in request order (FIFO): the reader thread
//! submits each parsed line to the [`Server`] and hands the ticket to a
//! writer thread that resolves them in submission order.  Head-of-line
//! waiting is bounded by the batcher deadline, and FIFO output means a
//! client can correlate by position as well as by `id`.

#![warn(missing_docs)]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;

use crate::backend::Value;
use crate::error::{anyhow, bail, Context, Result};
use crate::graph::InputKind;
use crate::json::Json;
use crate::tensor::{ITensor, Tensor};

use super::queue::BoundedQueue;
use super::{Engine, Server, Ticket};

/// The protocol version this build speaks; requests may pin it with the
/// optional `"v"` field and are rejected on mismatch (RFC 0002
/// versioning rules).
pub const PROTOCOL_VERSION: u64 = 1;

/// Parse one request line against an engine's input domain.  Returns the
/// request id (for the response envelope — `Json::Null` when the line is
/// too broken to carry one) alongside the decoded example or the error
/// to answer with.
pub fn parse_request(line: &str, engine: &dyn Engine) -> (Json, Result<Value>) {
    let doc = match Json::parse(line) {
        Ok(d) => d,
        Err(e) => return (Json::Null, Err(anyhow!("bad request JSON: {e}"))),
    };
    let id = doc.opt("id").cloned().unwrap_or(Json::Null);
    (id, decode_request(&doc, engine))
}

fn decode_request(doc: &Json, engine: &dyn Engine) -> Result<Value> {
    if doc.opt("id").is_none() {
        bail!("request is missing the required \"id\" field");
    }
    if let Some(v) = doc.opt("v") {
        let v = v.num().context("request \"v\" field")? as u64;
        if v != PROTOCOL_VERSION {
            bail!("unsupported protocol version {v} (this build speaks {PROTOCOL_VERSION})");
        }
    }
    let data = doc
        .opt("data")
        .ok_or_else(|| anyhow!("request is missing the required \"data\" field"))?
        .arr()
        .context("request \"data\" field")?;
    let shape = match doc.opt("shape") {
        Some(s) => s.shape().context("request \"shape\" field")?,
        None => engine.example_shape(),
    };
    let want: usize = shape.iter().product();
    if data.len() != want {
        bail!("request \"data\" has {} elements, shape {shape:?} wants {want}", data.len());
    }
    match engine.input() {
        InputKind::Image { .. } => {
            let data: Result<Vec<f32>> = data.iter().map(|j| j.num().map(|n| n as f32)).collect();
            Ok(Value::F32(Tensor { shape, data: data? }))
        }
        InputKind::Tokens { .. } => {
            // token ids must arrive as integers — silently truncating 5.9
            // to token 5 would serve a sequence the client never sent
            let data: Result<Vec<i32>> = data
                .iter()
                .map(|j| {
                    let n = j.num()?;
                    if n.fract() != 0.0 || !(i32::MIN as f64..=i32::MAX as f64).contains(&n) {
                        return Err(anyhow!("token id {n} is not an integer id"));
                    }
                    Ok(n as i32)
                })
                .collect();
            Ok(Value::I32(ITensor { shape, data: data? }))
        }
    }
}

/// Render one response line (no trailing newline): logits on success,
/// the error message otherwise.  Always single-line
/// ([`Json::render_min`]).
pub fn render_response(id: &Json, result: &Result<Tensor>) -> String {
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("id".to_string(), id.clone());
    match result {
        Ok(t) => {
            obj.insert(
                "shape".to_string(),
                Json::Arr(t.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
            );
            obj.insert(
                "logits".to_string(),
                Json::Arr(t.data.iter().map(|&v| Json::Num(v as f64)).collect()),
            );
        }
        Err(e) => {
            obj.insert("error".to_string(), Json::Str(e.to_string()));
        }
    }
    Json::Obj(obj).render_min()
}

/// Drive the server over one line stream: read → submit → answer, with
/// responses written in request order.  Returns the number of lines
/// answered once the input reaches EOF and every ticket resolved.
pub fn serve_stream<R: BufRead, W: Write + Send>(
    server: &Server,
    input: R,
    mut output: W,
) -> Result<usize> {
    // tickets ride a second bounded queue so reading (and batching)
    // stays ahead of the in-order writer
    let tickets: std::sync::Arc<BoundedQueue<(Json, Result<Ticket>)>> = BoundedQueue::new(4096);
    std::thread::scope(|s| -> Result<usize> {
        let writer_tickets = tickets.clone();
        let writer = s.spawn(move || -> Result<usize> {
            let mut served = 0usize;
            while let Some((id, outcome)) = writer_tickets.pop() {
                let result = outcome.and_then(Ticket::wait);
                let wrote = writeln!(output, "{}", render_response(&id, &result))
                    .and_then(|()| output.flush());
                if let Err(e) = wrote {
                    // the reader may be blocked pushing into a full
                    // tickets queue; closing it unblocks the reader so
                    // serve_stream returns instead of wedging (e.g. on
                    // EPIPE when the consumer of stdout went away)
                    writer_tickets.close();
                    return Err(anyhow!("writing response: {e}"));
                }
                served += 1;
            }
            Ok(served)
        });
        for line in input.lines() {
            let line = match line {
                Ok(l) => l,
                Err(e) => {
                    tickets.close();
                    let _ = writer.join();
                    return Err(anyhow!("reading request line: {e}"));
                }
            };
            if line.trim().is_empty() {
                continue;
            }
            let (id, parsed) = parse_request(&line, server.engine().as_ref());
            let outcome = parsed.and_then(|v| server.submit(v));
            if tickets.push((id, outcome)).is_err() {
                break; // writer side is gone
            }
        }
        tickets.close();
        writer.join().map_err(|_| anyhow!("response writer panicked"))?
    })
}

/// Serve JSONL over TCP: accept connections forever on
/// `{bind}:{port}`, one reader/writer pair per connection, all feeding
/// the same batcher — concurrent clients get co-batched.  Per-connection
/// failures are logged and do not stop the listener; this returns only
/// if the listener socket itself fails.
pub fn serve_tcp(server: &Server, bind: &str, port: u16) -> Result<()> {
    let listener =
        TcpListener::bind((bind, port)).with_context(|| format!("binding {bind}:{port}"))?;
    eprintln!("[serve] listening on {bind}:{port} (JSONL per connection)");
    std::thread::scope(|s| {
        for conn in listener.incoming() {
            match conn {
                Ok(stream) => {
                    s.spawn(move || {
                        let peer = stream
                            .peer_addr()
                            .map(|a| a.to_string())
                            .unwrap_or_else(|_| "?".into());
                        let reader = match stream.try_clone() {
                            Ok(r) => BufReader::new(r),
                            Err(e) => {
                                eprintln!("[serve] {peer}: {e}");
                                return;
                            }
                        };
                        match serve_stream(server, reader, &stream) {
                            Ok(n) => eprintln!("[serve] {peer}: answered {n} requests"),
                            Err(e) => eprintln!("[serve] {peer}: {e}"),
                        }
                    });
                }
                Err(e) => eprintln!("[serve] accept failed: {e}"),
            }
        }
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::QuantizedGraph;
    use std::sync::Arc;

    fn mlp_engine() -> Arc<QuantizedGraph> {
        Arc::new(crate::serve::test_fixture::lowered_mlp())
    }

    #[test]
    fn parse_accepts_default_and_explicit_shape() {
        let eng = mlp_engine();
        let data: Vec<String> = (0..192).map(|i| format!("{}", i as f32 * 0.01)).collect();
        let line = format!("{{\"id\": \"a\", \"data\": [{}]}}", data.join(","));
        let (id, v) = parse_request(&line, eng.as_ref());
        assert_eq!(id, Json::Str("a".into()));
        assert_eq!(v.unwrap().shape(), &[3, 8, 8]);

        let body = data.join(",");
        let line = format!("{{\"id\": 2, \"v\": 1, \"shape\": [3, 8, 8], \"data\": [{body}]}}");
        let (id, v) = parse_request(&line, eng.as_ref());
        assert_eq!(id, Json::Num(2.0));
        assert!(v.is_ok());
    }

    #[test]
    fn parse_rejects_bad_requests_with_best_effort_id() {
        let eng = mlp_engine();
        // broken JSON: no id recoverable
        let (id, v) = parse_request("{nope", eng.as_ref());
        assert_eq!(id, Json::Null);
        assert!(v.unwrap_err().to_string().contains("bad request JSON"));
        // well-formed but wrong element count: id still echoed
        let (id, v) = parse_request(r#"{"id": "x", "data": [1, 2]}"#, eng.as_ref());
        assert_eq!(id, Json::Str("x".into()));
        assert!(v.unwrap_err().to_string().contains("2 elements"));
        // missing id
        let (_, v) = parse_request(r#"{"data": [1]}"#, eng.as_ref());
        assert!(v.unwrap_err().to_string().contains("\"id\""));
        // future protocol version
        let (_, v) = parse_request(r#"{"id": 1, "v": 2, "data": [1]}"#, eng.as_ref());
        assert!(v.unwrap_err().to_string().contains("protocol version"));
    }

    #[test]
    fn token_requests_reject_non_integer_ids() {
        let eng = Arc::new(crate::serve::test_fixture::lowered("tiny_tf"));
        let ids: Vec<String> = (0..16).map(|i| (i % 64).to_string()).collect();
        let line = format!("{{\"id\": 1, \"data\": [{}]}}", ids.join(","));
        let (_, v) = parse_request(&line, eng.as_ref());
        assert!(v.is_ok());
        // 5.9 must not silently truncate to token 5
        let mut ids = ids;
        ids[3] = "5.9".to_string();
        let line = format!("{{\"id\": 1, \"data\": [{}]}}", ids.join(","));
        let (_, v) = parse_request(&line, eng.as_ref());
        assert!(v.unwrap_err().to_string().contains("not an integer"), "float id accepted");
    }

    #[test]
    fn response_lines_round_trip() {
        let id = Json::Str("r9".into());
        let ok = Ok(Tensor { shape: vec![2], data: vec![1.5, -0.25] });
        let line = render_response(&id, &ok);
        let doc = Json::parse(&line).unwrap();
        assert_eq!(doc.get("id").unwrap(), &id);
        assert_eq!(doc.get("shape").unwrap().shape().unwrap(), vec![2]);
        let logits = doc.get("logits").unwrap().arr().unwrap();
        assert_eq!(logits[1].num().unwrap() as f32, -0.25);

        let err: Result<Tensor> = Err(anyhow!("boom"));
        let doc = Json::parse(&render_response(&id, &err)).unwrap();
        assert_eq!(doc.get("error").unwrap().str().unwrap(), "boom");
    }
}
