//! Traffic record/replay harness (RFC 0006): capture live serve traffic
//! with arrival offsets, re-issue it later at N× speed.
//!
//! Two pieces:
//!
//! * [`TrafficRecorder`] — attached to a [`Registry`] via
//!   [`Registry::set_recorder`](super::registry::Registry::set_recorder)
//!   (`efqat serve --record trace.jsonl`).  Every *accepted* submission
//!   is appended as one JSON line carrying its arrival offset `t_us`,
//!   the resolved lane name (so model-less v1 traffic replays onto the
//!   same lane), and the example payload.
//! * [`replay`] — the driver: load a recorded trace
//!   ([`load_trace`]), start a registry with the same models, and
//!   [`replay`] re-issues every record at its recorded offset divided by
//!   a speed factor, draining replies FIFO on a side thread.  Replies
//!   come back in issue order with per-request latencies — the
//!   realistic-traffic leg of the `serve_latency` bench and the
//!   deterministic soak suite (`replay_soak`) are both this function in
//!   a loop.
//!
//! Recording is an I/O capture tool and allocates per request (payload
//! serialization) — unlike tracing ([`super::trace`]), it is not part
//! of the zero-allocation steady-state contract.

#![warn(missing_docs)]

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::backend::Value;
use crate::error::{anyhow, bail, Result};
use crate::json::Json;
use crate::tensor::{ITensor, Tensor};

use super::queue::BoundedQueue;
use super::registry::Reply;
use super::{Server, Ticket};

/// Replay file schema version (RFC 0006); the meta line every trace
/// leads with.  Readers reject other versions instead of guessing.
pub const REPLAY_VERSION: u64 = 1;

/// One captured request: arrival offset (µs since the recorder was
/// attached), the lane it was served by, and the example payload.
#[derive(Clone, Debug)]
pub struct ReplayRecord {
    /// Arrival offset in µs from the start of the capture.
    pub t_us: u64,
    /// Lane (model) name — always the *resolved* name, so replay routes
    /// identically even when the original request was model-less.
    pub model: String,
    /// The example, exactly as submitted (f32 image or i32 tokens).
    pub input: Value,
}

fn render_record(t_us: u64, model: &str, input: &Value) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("t_us".to_string(), Json::Num(t_us as f64));
    obj.insert("model".to_string(), Json::Str(model.to_string()));
    let (dtype, shape, data): (&str, &[usize], Vec<Json>) = match input {
        Value::F32(t) => ("f32", &t.shape, t.data.iter().map(|&v| Json::Num(v as f64)).collect()),
        Value::I32(t) => ("i32", &t.shape, t.data.iter().map(|&v| Json::Num(v as f64)).collect()),
    };
    obj.insert("dtype".to_string(), Json::Str(dtype.to_string()));
    let shape = shape.iter().map(|&d| Json::Num(d as f64)).collect();
    obj.insert("shape".to_string(), Json::Arr(shape));
    obj.insert("data".to_string(), Json::Arr(data));
    Json::Obj(obj).render_min()
}

fn meta_line() -> String {
    format!("{{\"replay_version\":{REPLAY_VERSION}}}")
}

/// Write `records` as an RFC 0006 replay trace at `path` (meta line
/// first, then one record per line).  Offsets must be non-decreasing —
/// the order a recorder would have captured them in.
pub fn write_trace(path: &str, records: &[ReplayRecord]) -> Result<()> {
    let mut out = String::new();
    out.push_str(&meta_line());
    out.push('\n');
    let mut last = 0u64;
    for r in records {
        if r.t_us < last {
            bail!("replay trace: offsets must be non-decreasing ({} after {last})", r.t_us);
        }
        last = r.t_us;
        out.push_str(&render_record(r.t_us, &r.model, &r.input));
        out.push('\n');
    }
    std::fs::write(path, out).map_err(|e| anyhow!("replay trace: cannot write {path}: {e}"))
}

/// Load an RFC 0006 replay trace written by [`write_trace`] or a
/// [`TrafficRecorder`].  Validates the version meta line, every record's
/// fields, and that offsets are non-decreasing.
pub fn load_trace(path: &str) -> Result<Vec<ReplayRecord>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("replay trace: cannot read {path}: {e}"))?;
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let meta = lines.next().ok_or_else(|| anyhow!("replay trace {path}: empty file"))?;
    let meta = Json::parse(meta).map_err(|e| anyhow!("replay trace {path}: bad meta line: {e}"))?;
    let v = meta.get("replay_version")?.usize()? as u64;
    if v != REPLAY_VERSION {
        bail!("replay trace {path}: replay_version {v}, this reader speaks {REPLAY_VERSION}");
    }
    let mut records = Vec::new();
    let mut last = 0u64;
    for (i, line) in lines.enumerate() {
        let rec = parse_record(line).map_err(|e| anyhow!("replay trace {path} record {i}: {e}"))?;
        if rec.t_us < last {
            bail!("replay trace {path} record {i}: t_us {} goes backwards after {last}", rec.t_us);
        }
        last = rec.t_us;
        records.push(rec);
    }
    Ok(records)
}

fn parse_record(line: &str) -> Result<ReplayRecord> {
    let doc = Json::parse(line)?;
    let t_us = doc.get("t_us")?.usize()? as u64;
    let model = doc.get("model")?.str()?.to_string();
    let dtype = doc.get("dtype")?.str()?;
    let shape = doc.get("shape")?.shape()?;
    let data = doc.get("data")?.arr()?;
    let len: usize = shape.iter().product();
    if data.len() != len {
        bail!("data length {} does not match shape {shape:?}", data.len());
    }
    let input = match dtype {
        "f32" => {
            let vals: Result<Vec<f32>> = data.iter().map(|j| Ok(j.num()? as f32)).collect();
            Value::F32(Tensor { shape, data: vals? })
        }
        "i32" => {
            let vals: Result<Vec<i32>> = data.iter().map(|j| Ok(j.num()? as i32)).collect();
            Value::I32(ITensor { shape, data: vals? })
        }
        other => bail!("unknown dtype {other:?} (want \"f32\" or \"i32\")"),
    };
    Ok(ReplayRecord { t_us, model, input })
}

struct RecorderInner {
    out: Box<dyn Write + Send>,
    records: u64,
}

/// Captures accepted submissions as an RFC 0006 replay trace
/// (`efqat serve --record trace.jsonl`).  The arrival clock starts when
/// the recorder is created; lines are written through a buffered writer
/// and pushed to disk by [`TrafficRecorder::flush`] (called by
/// [`Registry::flush_trace`](super::registry::Registry::flush_trace) at
/// shutdown).
pub struct TrafficRecorder {
    epoch: Instant,
    inner: Mutex<RecorderInner>,
}

impl TrafficRecorder {
    /// Record to a file at `path` (truncating), writing the version meta
    /// line immediately.
    pub fn create(path: &str) -> Result<TrafficRecorder> {
        let f = std::fs::File::create(path)
            .map_err(|e| anyhow!("traffic recorder: cannot create {path}: {e}"))?;
        TrafficRecorder::to_writer(Box::new(std::io::BufWriter::new(f)))
    }

    /// Record to an arbitrary sink (tests).
    pub fn to_writer(mut out: Box<dyn Write + Send>) -> Result<TrafficRecorder> {
        out.write_all(meta_line().as_bytes())
            .and_then(|()| out.write_all(b"\n"))
            .map_err(|e| anyhow!("traffic recorder: cannot write meta line: {e}"))?;
        let inner = Mutex::new(RecorderInner { out, records: 0 });
        Ok(TrafficRecorder { epoch: Instant::now(), inner })
    }

    /// Serialize one submission at the current arrival offset.  Called
    /// by [`Registry::submit`](super::registry::Registry::submit) before
    /// the request is offered to its lane; the line is only
    /// [`append`](TrafficRecorder::append)ed if admission succeeds.
    pub fn render_line(&self, model: &str, input: &Value) -> String {
        let t_us = self.epoch.elapsed().as_micros() as u64;
        render_record(t_us, model, input)
    }

    /// Append one pre-rendered record line.
    pub fn append(&self, line: String) {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let _ = inner.out.write_all(line.as_bytes());
        let _ = inner.out.write_all(b"\n");
        inner.records += 1;
    }

    /// Records appended so far.
    pub fn records(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).records
    }

    /// Push buffered lines to the underlying sink.
    pub fn flush(&self) {
        let _ = self.inner.lock().unwrap_or_else(|p| p.into_inner()).out.flush();
    }
}

/// Outcome of a [`replay`] run.  `replies[i]` and `lat_ms[i]` belong to
/// `records[i]` — replies are drained in issue order (the FIFO
/// contract), so position is identity.
pub struct ReplayReport {
    /// One reply per record, in issue order.  Bit-identity of
    /// `replies[i].logits` against an offline forward of `records[i]`
    /// is the mis-route check.
    pub replies: Vec<Reply>,
    /// Per-request latency in ms: submission to FIFO-drained reply.
    pub lat_ms: Vec<f64>,
    /// Submissions that bounced `overloaded` and were retried until
    /// accepted (replay never drops a record).
    pub retries: u64,
    /// Wall time of the whole replay.
    pub wall: Duration,
}

impl ReplayReport {
    /// Nearest-rank percentile over the per-request latencies, in ms.
    pub fn lat_pct(&self, q: f64) -> f64 {
        if self.lat_ms.is_empty() {
            return 0.0;
        }
        let mut sorted = self.lat_ms.clone();
        sorted.sort_by(f64::total_cmp);
        let rank = ((q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize).max(1);
        sorted[rank - 1]
    }
}

/// Re-issue `records` against `server` at `speed`× the captured pace:
/// record `i` is submitted at `t_us / speed` after the replay starts
/// (as close as sleep granularity allows; a replay that falls behind
/// submits immediately — offsets are deadlines, not rate limits).
///
/// An `overloaded` verdict is retried with a microsleep until the lane
/// accepts — a replay never drops a record; any other admission error
/// aborts.  Replies are drained FIFO concurrently with submission, so
/// intake backpressure stays realistic at high speedups.
pub fn replay(server: &Server, records: &[ReplayRecord], speed: f64) -> Result<ReplayReport> {
    if !(speed.is_finite() && speed > 0.0) {
        bail!("replay: speed must be finite and > 0, got {speed}");
    }
    type Drained = (Vec<Result<Reply>>, Vec<f64>);
    let inflight: Arc<BoundedQueue<(Instant, Ticket)>> = BoundedQueue::new(records.len().max(1));
    let t0 = Instant::now();
    let mut retries = 0u64;
    let (replies, lat_ms) = std::thread::scope(|scope| -> Result<Drained> {
        let drain = {
            let inflight = inflight.clone();
            scope.spawn(move || {
                let mut replies = Vec::new();
                let mut lat_ms = Vec::new();
                while let Some((submitted, ticket)) = inflight.pop() {
                    let reply = ticket.wait_reply();
                    lat_ms.push(submitted.elapsed().as_secs_f64() * 1e3);
                    replies.push(reply);
                }
                (replies, lat_ms)
            })
        };
        let mut submit_all = || -> Result<()> {
            for rec in records {
                let due = t0 + Duration::from_micros((rec.t_us as f64 / speed) as u64);
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                loop {
                    match server.try_submit(Some(&rec.model), rec.input.clone()) {
                        Ok(ticket) => {
                            if inflight.push((Instant::now(), ticket)).is_err() {
                                bail!("replay: inflight queue closed early");
                            }
                            break;
                        }
                        Err(e) if e.code() == "overloaded" => {
                            retries += 1;
                            std::thread::sleep(Duration::from_micros(200));
                        }
                        Err(e) => bail!("replay: record for {:?} rejected: {e}", rec.model),
                    }
                }
            }
            Ok(())
        };
        let submitted = submit_all();
        inflight.close();
        let drained = drain.join().expect("replay drain thread");
        submitted?;
        Ok(drained)
    })?;
    let wall = t0.elapsed();
    let mut out_replies = Vec::with_capacity(replies.len());
    for r in replies {
        out_replies.push(r?);
    }
    Ok(ReplayReport { replies: out_replies, lat_ms, retries, wall })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn records() -> Vec<ReplayRecord> {
        vec![
            ReplayRecord {
                t_us: 0,
                model: "a".to_string(),
                input: Value::F32(Tensor { shape: vec![2, 2], data: vec![0.5, -1.25, 3.0, 0.1] }),
            },
            ReplayRecord {
                t_us: 1500,
                model: "b".to_string(),
                input: Value::I32(ITensor { shape: vec![3], data: vec![5, 0, 63] }),
            },
        ]
    }

    #[test]
    fn trace_file_round_trips_bitwise() {
        let dir = std::env::temp_dir().join("efqat_replay_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let path = path.to_str().unwrap();
        write_trace(path, &records()).unwrap();
        let back = load_trace(path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!((back[0].t_us, back[0].model.as_str()), (0, "a"));
        match (&back[0].input, &records()[0].input) {
            (Value::F32(got), Value::F32(want)) => {
                assert_eq!(got.shape, want.shape);
                // f32 → JSON text → f32 is exact (f64 shortest round-trip)
                assert_eq!(got.data, want.data);
            }
            _ => panic!("dtype lost in round trip"),
        }
        match &back[1].input {
            Value::I32(t) => assert_eq!(t.data, vec![5, 0, 63]),
            _ => panic!("i32 record decoded as f32"),
        }
    }

    #[test]
    fn load_rejects_bad_version_and_backwards_offsets() {
        let dir = std::env::temp_dir().join("efqat_replay_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = dir.join("v9.jsonl");
        std::fs::write(&p1, "{\"replay_version\":9}\n").unwrap();
        let err = load_trace(p1.to_str().unwrap()).unwrap_err().to_string();
        assert!(err.contains("replay_version"), "{err}");
        let mut recs = records();
        recs[1].t_us = 0;
        recs[0].t_us = 10;
        let p2 = dir.join("backwards.jsonl");
        assert!(write_trace(p2.to_str().unwrap(), &recs).is_err());
        let r10 = render_record(10, "a", &records()[0].input);
        let r0 = render_record(0, "a", &records()[0].input);
        let text = format!("{}\n{r10}\n{r0}\n", meta_line());
        std::fs::write(&p2, text).unwrap();
        let err = load_trace(p2.to_str().unwrap()).unwrap_err().to_string();
        assert!(err.contains("backwards"), "{err}");
    }

    #[test]
    fn recorder_writes_meta_then_records() {
        struct SharedBuf(Arc<Mutex<Vec<u8>>>);
        impl Write for SharedBuf {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let sink = Arc::new(Mutex::new(Vec::new()));
        let rec = TrafficRecorder::to_writer(Box::new(SharedBuf(sink.clone()))).unwrap();
        let input = records()[0].input.clone();
        let line = rec.render_line("m", &input);
        rec.append(line);
        rec.flush();
        assert_eq!(rec.records(), 1);
        let text = String::from_utf8(sink.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let meta = Json::parse(lines[0]).unwrap();
        assert_eq!(meta.get("replay_version").unwrap().usize().unwrap(), 1);
        let rec0 = parse_record(lines[1]).unwrap();
        assert_eq!(rec0.model, "m");
        assert_eq!(rec0.input.shape(), &[2, 2]);
    }

    #[test]
    fn replay_rejects_bad_speed() {
        let server = Server::single(
            Arc::new(super::super::test_fixture::lowered_mlp()),
            super::super::ServeCfg::default(),
        );
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert!(replay(&server, &[], bad).is_err(), "speed {bad} must be rejected");
        }
        let report = replay(&server, &[], 1.0).unwrap();
        assert!(report.replies.is_empty() && report.retries == 0);
        assert_eq!(report.lat_pct(0.95), 0.0);
    }
}
