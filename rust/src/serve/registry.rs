//! Multi-model serving registry with zero-downtime checkpoint hot swap
//! (RFC `docs/rfcs/0005-serving-registry.md`).
//!
//! The registry holds one *lane* per model name — an intake queue, a
//! batcher thread, and a worker pool — and one [`EngineSlot`] naming the
//! engine that lane currently answers with:
//!
//! ```text
//!            ┌─ lane "resnet": intake ─► batcher ─► workers ──► Mutex<EngineSlot> gen 3
//!  Registry ─┼─ lane "mlp":    intake ─► batcher ─► workers ──► Mutex<EngineSlot> gen 1
//!            └─ default model, per-model draining flags, stats
//! ```
//!
//! * **Hot swap** ([`Registry::install`] over an existing name) replaces
//!   the slot's `Arc<dyn Engine>` under the slot lock and bumps the
//!   generation.  Workers clone the slot *per batch*, so in-flight
//!   batches keep answering from the pre-swap engine; the old `Arc` is
//!   dropped when its last batch completes.  Nothing queued is lost and
//!   no request is mis-routed: each [`Reply`] carries the fingerprint
//!   and generation of the engine that actually computed it.
//! * **Fingerprints** are the RFC 0001 bundle SHA-256
//!   ([`crate::bundle::fingerprint`]) — the swap-safety primitive: a
//!   swap is observable, and two deployments of the same checkpoint are
//!   provably the same arithmetic.
//! * **Admission control**: submission never blocks.  A full intake is a
//!   typed [`SubmitError::Overloaded`] rejection (one hot model cannot
//!   starve the rest — each lane has its own bounded queue), and a model
//!   being retired answers [`SubmitError::Draining`] while its queued
//!   requests drain on the outgoing engine.
//!
//! Swap safety: an engine installed over an existing model must keep the
//! input geometry (`InputKind`), class count, and vocabulary of the
//! engine it replaces, so a request validated or decoded against the old
//! engine is still well-formed for the new one.  Cross-geometry changes
//! are a new model name, not a swap.

#![warn(missing_docs)]

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::error::{bail, Error, Result};
use crate::tensor::Tensor;

use super::batcher;
use super::queue::{oneshot, BoundedQueue, TryPush};
use super::replay::TrafficRecorder;
use super::trace::{LaneTrace, Span, TraceStats, TraceSubscriber};
use super::worker::{self, Engine, Request};
use super::{ServeCfg, Ticket};

/// A poisoned registry lock only means some thread panicked mid-update;
/// the registry state itself is always coherent (slot replacement is a
/// single assignment), so every lock recovers instead of propagating.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn read<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|p| p.into_inner())
}

fn write<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|p| p.into_inner())
}

/// The engine a lane currently answers with, plus the identity a
/// [`Reply`] echoes back.  Workers clone this per batch (three `Arc`
/// bumps and a `u64` — alloc-free), so a swap lands between batches,
/// never inside one.
#[derive(Clone)]
pub struct EngineSlot {
    /// The engine executing this lane's batches.
    pub engine: Arc<dyn Engine>,
    /// Model name the lane serves under (registry key, not
    /// [`Engine::model`] — one architecture can serve under many names).
    pub model: Arc<str>,
    /// Checkpoint fingerprint: RFC 0001 bundle SHA-256 hex, or
    /// `"unversioned"` for engines installed without provenance.
    pub fingerprint: Arc<str>,
    /// Monotonic per-model install counter; starts at 1, bumped by every
    /// swap.  Distinguishes re-installs of an identical checkpoint.
    pub generation: u64,
}

/// One answered request: the logits plus the identity of the engine that
/// computed them — the proof a hot swap routed nothing to the wrong
/// graph.
#[derive(Clone)]
pub struct Reply {
    /// Per-example logits (batch dimension already split away).
    pub logits: Tensor,
    /// Model name the request was served under.
    pub model: Arc<str>,
    /// Fingerprint of the engine that computed [`Self::logits`].
    pub fingerprint: Arc<str>,
    /// Generation of that engine (see [`EngineSlot::generation`]).
    pub generation: u64,
}

/// Typed admission-control verdicts: why a submission was not accepted.
/// Each maps to a stable protocol error code ([`SubmitError::code`])
/// so clients can react mechanically (back off, re-resolve, fail over).
#[derive(Debug)]
pub enum SubmitError {
    /// No model registered under the requested name.
    UnknownModel {
        /// The name the request asked for.
        model: String,
        /// Names the registry does serve (for the error message).
        known: Vec<String>,
    },
    /// A model-less (v1) request arrived but no default model is set.
    NoDefaultModel,
    /// The model's intake queue is at capacity; retry with backoff.
    Overloaded {
        /// The model whose lane is full.
        model: String,
        /// Its configured queue capacity.
        cap: usize,
    },
    /// The model is being retired; queued requests drain, new ones bounce.
    Draining {
        /// The model being retired.
        model: String,
    },
    /// The serving runtime is not running (never started or shut down).
    Shutdown {
        /// The model the request asked for.
        model: String,
    },
    /// The example failed validation against the model's input domain.
    Invalid(Error),
}

impl SubmitError {
    /// Stable machine-readable code, used verbatim as the RFC 0002 v2
    /// response `code` field.
    pub fn code(&self) -> &'static str {
        match self {
            SubmitError::UnknownModel { .. } => "unknown_model",
            SubmitError::NoDefaultModel => "no_default_model",
            SubmitError::Overloaded { .. } => "overloaded",
            SubmitError::Draining { .. } => "draining",
            SubmitError::Shutdown { .. } => "shutdown",
            SubmitError::Invalid(_) => "invalid",
        }
    }
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::UnknownModel { model, known } => {
                write!(f, "unknown model {model:?}; serving: [{}]", known.join(", "))
            }
            SubmitError::NoDefaultModel => {
                write!(f, "request names no model and no default model is configured")
            }
            SubmitError::Overloaded { model, cap } => {
                write!(f, "{model}: intake queue full ({cap} queued); retry with backoff")
            }
            SubmitError::Draining { model } => {
                write!(f, "{model}: draining (being retired); pick another model")
            }
            SubmitError::Shutdown { model } => {
                write!(f, "{model}: serving runtime is not running")
            }
            SubmitError::Invalid(e) => write!(f, "{e}"),
        }
    }
}

impl From<SubmitError> for Error {
    fn from(e: SubmitError) -> Error {
        Error::msg(format!("serve [{}]: {e}", e.code()))
    }
}

/// Live per-model counters for the stats surface (`{"stats": true}`
/// requests and `efqat serve` shutdown logs) — swaps are observable.
#[derive(Clone, Debug)]
pub struct ModelStats {
    /// Model name.
    pub model: String,
    /// Active engine's checkpoint fingerprint.
    pub fingerprint: String,
    /// Active engine's generation (bumped per swap).
    pub generation: u64,
    /// Requests accepted but not yet batched.
    pub queued: usize,
    /// Intake queue capacity (0 until the lane starts).
    pub capacity: usize,
    /// Whether the model is being retired.
    pub draining: bool,
    /// EWMA batch fill ratio: mean executed batch size over
    /// `--batch.max` (0 until the lane has executed a batch).
    pub batch_fill: f64,
    /// Live per-stage latency percentiles (RFC 0006); `None` until the
    /// lane starts.
    pub trace: Option<TraceStats>,
}

/// One model's lane: identity, the swappable engine slot, and the
/// queue/threads that exist once the registry is started.
struct ModelEntry {
    name: Arc<str>,
    slot: Mutex<EngineSlot>,
    draining: AtomicBool,
    /// Intake queue; set exactly once when the lane starts.  A retired
    /// lane is never restarted — re-installing a retired name makes a
    /// fresh entry.
    intake: OnceLock<Arc<BoundedQueue<Request>>>,
    /// Intake capacity, mirrored out of [`ServeCfg`] for stats.
    capacity: AtomicUsize,
    threads: Mutex<Vec<JoinHandle<()>>>,
    /// Per-lane trace aggregation (RFC 0006); set when the lane starts,
    /// with the subscriber set snapshotted at that moment.
    trace: OnceLock<Arc<LaneTrace>>,
}

struct Inner {
    models: RwLock<BTreeMap<String, Arc<ModelEntry>>>,
    default_model: RwLock<Option<String>>,
    /// `Some(cfg)` while lanes are running; installs then start their
    /// lane immediately.  Lock order: `models` before `running`; never
    /// acquire `models` while holding `running`.
    running: Mutex<Option<ServeCfg>>,
    /// Shared monotonic origin for every lane's trace-event offsets, so
    /// multi-model traces interleave on one clock.
    epoch: Instant,
    /// Trace subscribers, fanned into every lane started after
    /// registration.  Lock order: leaf (never held across other locks).
    subscribers: Mutex<Vec<Arc<dyn TraceSubscriber>>>,
    /// Traffic recorder (`efqat serve --record`): accepted submissions
    /// are appended as RFC 0006 replay records.
    recorder: RwLock<Option<Arc<TrafficRecorder>>>,
}

/// Handle to the shared registry state.  Cheap to clone; every clone
/// sees the same models, default, and lanes.
#[derive(Clone)]
pub struct Registry {
    inner: Arc<Inner>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    /// An empty registry: no models, no default, lanes not started.
    pub fn new() -> Registry {
        Registry {
            inner: Arc::new(Inner {
                models: RwLock::new(BTreeMap::new()),
                default_model: RwLock::new(None),
                running: Mutex::new(None),
                epoch: Instant::now(),
                subscribers: Mutex::new(Vec::new()),
                recorder: RwLock::new(None),
            }),
        }
    }

    /// Register a trace subscriber (RFC 0006).  Lanes snapshot the
    /// subscriber set when they start, so register *before*
    /// [`Registry::start`] (or before installing a model into a running
    /// registry) to see that lane's events.
    pub fn subscribe(&self, sub: Arc<dyn TraceSubscriber>) {
        lock(&self.inner.subscribers).push(sub);
    }

    /// Attach a traffic recorder (`efqat serve --record`): every
    /// *accepted* submission is appended as an RFC 0006 replay record
    /// with its arrival offset.  Pass-through of the handle so callers
    /// can flush/inspect it; replaces any previous recorder.
    pub fn set_recorder(&self, rec: Arc<TrafficRecorder>) {
        *write(&self.inner.recorder) = Some(rec);
    }

    /// Install `engine` under `name` with its checkpoint `fingerprint`
    /// (see [`crate::bundle::fingerprint`]; `"unversioned"` is the
    /// convention for engines without provenance).
    ///
    /// First install of a name creates the model (and becomes the
    /// default model if none is set); installing over an existing name
    /// is the *hot swap*: the new engine must match the old one's input
    /// geometry, class count, and vocabulary, and takes over between
    /// batches while in-flight work completes on the old `Arc`.
    pub fn install(&self, name: &str, engine: Arc<dyn Engine>, fingerprint: &str) -> Result<()> {
        if name.is_empty() {
            bail!("registry: model name must be non-empty");
        }
        let mut models = write(&self.inner.models);
        if let Some(entry) = models.get(name) {
            if entry.draining.load(Ordering::SeqCst) {
                bail!("registry: cannot install {name:?} while it is draining");
            }
            let mut slot = lock(&entry.slot);
            let old = &slot.engine;
            if old.input() != engine.input()
                || old.classes() != engine.classes()
                || old.vocab() != engine.vocab()
            {
                bail!(
                    "registry: swap for {name:?} changes the serving contract \
                     (input/classes/vocab); install under a new model name instead"
                );
            }
            *slot = EngineSlot {
                engine,
                model: entry.name.clone(),
                fingerprint: Arc::from(fingerprint),
                generation: slot.generation + 1,
            };
            return Ok(());
        }
        let name_arc: Arc<str> = Arc::from(name);
        let entry = Arc::new(ModelEntry {
            name: name_arc.clone(),
            slot: Mutex::new(EngineSlot {
                engine,
                model: name_arc,
                fingerprint: Arc::from(fingerprint),
                generation: 1,
            }),
            draining: AtomicBool::new(false),
            intake: OnceLock::new(),
            capacity: AtomicUsize::new(0),
            threads: Mutex::new(Vec::new()),
            trace: OnceLock::new(),
        });
        // a registry already running gives the new model its lane now
        if let Some(cfg) = *lock(&self.inner.running) {
            let subs = lock(&self.inner.subscribers).clone();
            start_lane(&entry, cfg, self.inner.epoch, subs);
        }
        models.insert(name.to_string(), entry);
        drop(models);
        let mut default = write(&self.inner.default_model);
        if default.is_none() {
            *default = Some(name.to_string());
        }
        Ok(())
    }

    /// Make `name` the model that answers model-less (v1) requests.
    pub fn set_default(&self, name: &str) -> Result<()> {
        if !read(&self.inner.models).contains_key(name) {
            bail!("registry: cannot default to unknown model {name:?}");
        }
        *write(&self.inner.default_model) = Some(name.to_string());
        Ok(())
    }

    /// The model answering model-less (v1) requests, if any.
    pub fn default_model(&self) -> Option<String> {
        read(&self.inner.default_model).clone()
    }

    /// Registered model names, sorted.
    pub fn models(&self) -> Vec<String> {
        read(&self.inner.models).keys().cloned().collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        read(&self.inner.models).len()
    }

    /// Whether no models are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resolve `model` (or the default) to its current engine slot — the
    /// protocol driver decodes request payloads against this engine.
    /// The clone is a snapshot: a swap after resolution is fine because
    /// swaps preserve the serving contract (see [`Registry::install`]).
    pub fn engine_for(&self, model: Option<&str>) -> Result<EngineSlot, SubmitError> {
        let entry = self.entry_for(model)?;
        let slot = lock(&entry.slot);
        Ok(slot.clone())
    }

    fn entry_for(&self, model: Option<&str>) -> Result<Arc<ModelEntry>, SubmitError> {
        let name = match model {
            Some(m) => m.to_string(),
            None => self.default_model().ok_or(SubmitError::NoDefaultModel)?,
        };
        let models = read(&self.inner.models);
        match models.get(&name) {
            Some(e) => Ok(e.clone()),
            None => Err(SubmitError::UnknownModel {
                model: name,
                known: models.keys().cloned().collect(),
            }),
        }
    }

    /// Submit one example to `model` (or the default model for `None`).
    /// Never blocks: the example is validated against the model's
    /// current engine, then offered to its intake queue; a full queue is
    /// [`SubmitError::Overloaded`], a retiring model
    /// [`SubmitError::Draining`].
    pub fn submit(&self, model: Option<&str>, input: crate::backend::Value) -> SubmitResult {
        let mut span = Span::begin();
        let entry = self.entry_for(model)?;
        if entry.draining.load(Ordering::SeqCst) {
            return Err(SubmitError::Draining { model: entry.name.to_string() });
        }
        let engine = lock(&entry.slot).engine.clone();
        engine.validate_example(&input).map_err(SubmitError::Invalid)?;
        let Some(intake) = entry.intake.get() else {
            return Err(SubmitError::Shutdown { model: entry.name.to_string() });
        };
        // pre-render the replay record while we still borrow the input;
        // it is written only if the submission is accepted
        let recorder = read(&self.inner.recorder).clone();
        let line = recorder.as_ref().map(|r| r.render_line(&entry.name, &input));
        let (tx, rx) = oneshot();
        span.admitted = Instant::now();
        match intake.try_push(Request { input, tx, span }) {
            Ok(()) => {
                if let (Some(r), Some(l)) = (&recorder, line) {
                    r.append(l);
                }
                Ok(Ticket { rx })
            }
            Err(TryPush::Full(_)) => Err(SubmitError::Overloaded {
                model: entry.name.to_string(),
                cap: entry.capacity.load(Ordering::Relaxed),
            }),
            // closed intake during retire reads as draining, not shutdown
            Err(TryPush::Closed(_)) => {
                if entry.draining.load(Ordering::SeqCst) {
                    Err(SubmitError::Draining { model: entry.name.to_string() })
                } else {
                    Err(SubmitError::Shutdown { model: entry.name.to_string() })
                }
            }
        }
    }

    /// Start every model's lane (intake + batcher + workers) with `cfg`.
    /// At most once per registry; models installed later get their lane
    /// on install.
    pub fn start(&self, cfg: ServeCfg) -> Result<()> {
        let models = read(&self.inner.models);
        let mut running = lock(&self.inner.running);
        if running.is_some() {
            bail!("registry: serving lanes already started");
        }
        *running = Some(cfg);
        drop(running);
        let subs = lock(&self.inner.subscribers).clone();
        for entry in models.values() {
            start_lane(entry, cfg, self.inner.epoch, subs.clone());
        }
        Ok(())
    }

    /// Retire `name`: refuse new submissions ([`SubmitError::Draining`]),
    /// drain its queued requests on the outgoing engine, join its lane,
    /// then remove it (clearing the default if it pointed there).
    /// Blocks until the lane is fully drained.
    pub fn retire(&self, name: &str) -> Result<()> {
        let entry = match read(&self.inner.models).get(name) {
            Some(e) => e.clone(),
            None => bail!("registry: cannot retire unknown model {name:?}"),
        };
        entry.draining.store(true, Ordering::SeqCst);
        if let Some(intake) = entry.intake.get() {
            intake.close(); // draining close: everything queued is answered
        }
        let threads: Vec<JoinHandle<()>> = lock(&entry.threads).drain(..).collect();
        for t in threads {
            let _ = t.join();
        }
        write(&self.inner.models).remove(name);
        let mut default = write(&self.inner.default_model);
        if default.as_deref() == Some(name) {
            *default = None;
        }
        drop(default);
        // the retired lane's last events are buffered in subscribers
        self.flush_trace();
        Ok(())
    }

    /// Flush every trace subscriber and the traffic recorder (if any) to
    /// their underlying sinks.
    pub fn flush_trace(&self) {
        let subs = lock(&self.inner.subscribers).clone();
        for s in &subs {
            s.flush();
        }
        if let Some(r) = read(&self.inner.recorder).clone() {
            r.flush();
        }
    }

    /// Total requests queued (accepted, not yet batched) across models.
    pub fn pending(&self) -> usize {
        read(&self.inner.models)
            .values()
            .filter_map(|e| e.intake.get().map(|q| q.len()))
            .sum()
    }

    /// Per-model live counters, sorted by model name.
    pub fn stats(&self) -> Vec<ModelStats> {
        let models = read(&self.inner.models);
        // lock order: `models` before `running` (documented on Inner)
        let max_batch = (*lock(&self.inner.running)).map(|c| c.batch.max_batch.max(1));
        models
            .values()
            .map(|e| {
                let slot = lock(&e.slot);
                let trace = e.trace.get().map(|t| t.stats());
                let batch_fill = match (&trace, max_batch) {
                    (Some(t), Some(mb)) => t.mean_batch / mb as f64,
                    _ => 0.0,
                };
                ModelStats {
                    model: e.name.to_string(),
                    fingerprint: slot.fingerprint.to_string(),
                    generation: slot.generation,
                    queued: e.intake.get().map(|q| q.len()).unwrap_or(0),
                    capacity: e.capacity.load(Ordering::Relaxed),
                    draining: e.draining.load(Ordering::SeqCst),
                    batch_fill,
                    trace,
                }
            })
            .collect()
    }

    /// Close every lane's intake, drain queued work through the
    /// workers, and join all threads.  Idempotent; the registry cannot
    /// be restarted afterwards (build a new one).
    pub fn shutdown(&self) {
        *lock(&self.inner.running) = None;
        let entries: Vec<Arc<ModelEntry>> = read(&self.inner.models).values().cloned().collect();
        for entry in &entries {
            if let Some(intake) = entry.intake.get() {
                intake.close();
            }
        }
        for entry in &entries {
            let threads: Vec<JoinHandle<()>> = lock(&entry.threads).drain(..).collect();
            for t in threads {
                let _ = t.join();
            }
        }
        self.flush_trace();
    }
}

/// Convenience alias for [`Registry::submit`]'s typed result.
pub type SubmitResult = std::result::Result<Ticket, SubmitError>;

/// Spawn one lane (intake queue, batcher, workers) for `entry`.  A lane
/// starts at most once; re-entry (retired name re-installed onto the
/// same entry) is impossible because retire removes the entry.  The
/// lane's [`LaneTrace`] snapshots the registry's subscriber set at this
/// moment and is shared by every worker in the pool.
fn start_lane(
    entry: &Arc<ModelEntry>,
    cfg: ServeCfg,
    epoch: Instant,
    subs: Vec<Arc<dyn TraceSubscriber>>,
) {
    let intake: Arc<BoundedQueue<Request>> = BoundedQueue::new(cfg.queue_cap);
    if entry.intake.set(intake.clone()).is_err() {
        return;
    }
    entry.capacity.store(cfg.queue_cap.max(1), Ordering::Relaxed);
    let trace = Arc::new(LaneTrace::new(entry.name.clone(), epoch, subs));
    let _ = entry.trace.set(trace.clone());
    // small batch buffer: enough to keep every worker busy without
    // letting latency hide in a deep intermediate queue
    let batches: Arc<BoundedQueue<Vec<Request>>> = BoundedQueue::new(cfg.workers.max(1) * 2);
    let mut threads = lock(&entry.threads);
    {
        let (rq, bq) = (intake, batches.clone());
        threads.push(
            std::thread::Builder::new()
                .name(format!("efqat-{}-batcher", entry.name))
                .spawn(move || batcher::run(&rq, &bq, cfg.batch))
                .expect("spawn batcher"),
        );
    }
    for i in 0..cfg.workers.max(1) {
        let (e, bq, tr) = (entry.clone(), batches.clone(), trace.clone());
        threads.push(
            std::thread::Builder::new()
                .name(format!("efqat-{}-worker-{i}", entry.name))
                .spawn(move || worker::run(&e.slot, &bq, &tr))
                .expect("spawn worker"),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_fixture;
    use super::*;
    use crate::backend::Value;
    use crate::tensor::Tensor;

    fn image(seed: u64) -> Value {
        let mut rng = crate::rng::Pcg64::new(seed);
        Value::F32(Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) })
    }

    fn mlp() -> Arc<dyn Engine> {
        Arc::new(test_fixture::lowered_mlp())
    }

    #[test]
    fn first_install_becomes_default_and_set_default_validates() {
        let reg = Registry::new();
        assert!(reg.is_empty());
        reg.install("a", mlp(), "fp-a").unwrap();
        reg.install("b", mlp(), "fp-b").unwrap();
        assert_eq!(reg.default_model().as_deref(), Some("a"));
        assert_eq!(reg.models(), vec!["a".to_string(), "b".to_string()]);
        reg.set_default("b").unwrap();
        assert_eq!(reg.default_model().as_deref(), Some("b"));
        assert!(reg.set_default("nope").is_err());
    }

    #[test]
    fn swap_bumps_generation_and_rejects_geometry_changes() {
        let reg = Registry::new();
        reg.install("m", mlp(), "fp-1").unwrap();
        assert_eq!(reg.engine_for(Some("m")).unwrap().generation, 1);
        reg.install("m", mlp(), "fp-2").unwrap();
        let slot = reg.engine_for(Some("m")).unwrap();
        assert_eq!(slot.generation, 2);
        assert_eq!(&*slot.fingerprint, "fp-2");
        // tiny_tf is a token model: swapping it over an image model
        // would break in-flight decoded requests — refused
        let tf: Arc<dyn Engine> = Arc::new(test_fixture::lowered("tiny_tf"));
        let err = reg.install("m", tf, "fp-3").unwrap_err().to_string();
        assert!(err.contains("serving contract"), "{err}");
    }

    #[test]
    fn submit_routes_and_reports_typed_errors() {
        let reg = Registry::new();
        // nothing installed: no default to fall back to
        assert!(matches!(reg.submit(None, image(0)), Err(SubmitError::NoDefaultModel)));
        reg.install("m", mlp(), "fp-1").unwrap();
        // installed but lanes not started
        match reg.submit(Some("m"), image(0)) {
            Err(e @ SubmitError::Shutdown { .. }) => assert_eq!(e.code(), "shutdown"),
            other => panic!("want Shutdown, got {:?}", other.err().map(|e| e.to_string())),
        }
        match reg.submit(Some("ghost"), image(0)) {
            Err(e @ SubmitError::UnknownModel { .. }) => assert_eq!(e.code(), "unknown_model"),
            other => panic!("want UnknownModel, got {:?}", other.err().map(|e| e.to_string())),
        }
        reg.start(ServeCfg::default()).unwrap();
        // malformed examples are rejected before they join a batch
        let bad = Value::F32(Tensor::zeros(&[3, 4, 4]));
        assert!(matches!(reg.submit(Some("m"), bad), Err(SubmitError::Invalid(_))));
        let reply = reg.submit(None, image(1)).unwrap().wait_reply().unwrap();
        assert_eq!(&*reply.model, "m");
        assert_eq!(&*reply.fingerprint, "fp-1");
        assert_eq!(reply.generation, 1);
        assert_eq!(reply.logits.shape, vec![10]);
        reg.shutdown();
        match reg.submit(Some("m"), image(2)) {
            Err(e @ SubmitError::Shutdown { .. }) => assert_eq!(e.code(), "shutdown"),
            other => panic!("want Shutdown, got {:?}", other.err().map(|e| e.to_string())),
        }
    }

    #[test]
    fn retire_removes_model_and_clears_default() {
        let reg = Registry::new();
        reg.install("m", mlp(), "fp-1").unwrap();
        reg.start(ServeCfg::default()).unwrap();
        reg.retire("m").unwrap();
        assert!(reg.models().is_empty());
        assert_eq!(reg.default_model(), None);
        assert!(reg.retire("m").is_err());
        reg.shutdown();
    }

    #[test]
    fn stats_surface_fingerprint_generation_and_capacity() {
        let reg = Registry::new();
        reg.install("m", mlp(), "fp-1").unwrap();
        let st = &reg.stats()[0];
        assert_eq!((st.capacity, st.generation, st.draining), (0, 1, false));
        let cfg = ServeCfg::builder().queue_cap(7).build().unwrap();
        reg.start(cfg).unwrap();
        reg.install("m", mlp(), "fp-2").unwrap();
        let st = &reg.stats()[0];
        assert_eq!(st.model, "m");
        assert_eq!(st.fingerprint, "fp-2");
        assert_eq!((st.capacity, st.generation), (7, 2));
        reg.shutdown();
    }
}
