//! std-only concurrency primitives of the serving runtime: a bounded
//! MPSC queue and a oneshot result channel, both built on
//! `Mutex` + `Condvar` (no external crates, matching the zero-dep
//! default build).
//!
//! The request path is `submitters → [BoundedQueue<Request>] → batcher →
//! [BoundedQueue<Vec<Request>>] → workers`, with each request carrying a
//! [`OneshotSender`] the worker resolves — see [`crate::serve`] for the
//! full topology.
//!
//! Shutdown is *draining* by design: [`BoundedQueue::close`] rejects new
//! pushes but lets consumers pop everything already queued, so every
//! accepted request is answered before the server's threads exit.

#![warn(missing_docs)]

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

/// Rejected [`BoundedQueue::try_push`], handing the item back so the
/// caller can answer it (the serving registry's admission control).
#[derive(Debug)]
pub enum TryPush<T> {
    /// The queue was at capacity.
    Full(T),
    /// The queue was closed.
    Closed(T),
}

/// Outcome of a deadline-bounded pop ([`BoundedQueue::pop_deadline`]).
#[derive(Debug)]
pub enum Popped<T> {
    /// An item arrived before the deadline.
    Item(T),
    /// The deadline passed with the queue still empty (and open).
    TimedOut,
    /// The queue is closed and fully drained; no item will ever arrive.
    Closed,
}

struct QueueState<T> {
    buf: VecDeque<T>,
    closed: bool,
}

/// A bounded multi-producer multi-consumer FIFO queue.
///
/// * `push` blocks while the queue is full (backpressure toward
///   submitters) and fails once the queue is closed;
/// * `pop` blocks while the queue is empty and returns `None` only when
///   the queue is closed *and* drained — close never drops queued items;
/// * `pop_deadline` is the batcher's deadline wait: an item, a timeout,
///   or closed-and-drained, whichever comes first.
///
/// Shared by reference (`Arc<BoundedQueue<T>>`) between producer and
/// consumer threads.
pub struct BoundedQueue<T> {
    cap: usize,
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

/// Ignore mutex poisoning: queue state is a plain `VecDeque` + flag, so
/// it is never left mid-invariant, and shutdown paths must keep working
/// even after a worker thread panicked.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl<T> BoundedQueue<T> {
    /// Create a queue holding at most `cap` items (`cap` is clamped to
    /// at least 1), ready to share via `Arc`.
    pub fn new(cap: usize) -> Arc<BoundedQueue<T>> {
        Arc::new(BoundedQueue {
            cap: cap.max(1),
            state: Mutex::new(QueueState { buf: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        })
    }

    /// Enqueue `v`, blocking while the queue is full.  Returns `Err(v)`
    /// (handing the item back) if the queue is closed.
    pub fn push(&self, v: T) -> Result<(), T> {
        let mut st = lock(&self.state);
        loop {
            if st.closed {
                return Err(v);
            }
            if st.buf.len() < self.cap {
                st.buf.push_back(v);
                self.not_empty.notify_one();
                return Ok(());
            }
            st = self.not_full.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Enqueue `v` without blocking: admission control for the serving
    /// registry.  A full queue hands the item back as
    /// [`TryPush::Full`] (the caller turns it into a typed
    /// `overloaded` rejection) instead of parking the submitter; a
    /// closed queue hands it back as [`TryPush::Closed`].
    pub fn try_push(&self, v: T) -> Result<(), TryPush<T>> {
        let mut st = lock(&self.state);
        if st.closed {
            return Err(TryPush::Closed(v));
        }
        if st.buf.len() >= self.cap {
            return Err(TryPush::Full(v));
        }
        st.buf.push_back(v);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Dequeue, blocking while the queue is empty.  Returns `None` only
    /// when the queue is closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = lock(&self.state);
        loop {
            if let Some(v) = st.buf.pop_front() {
                self.not_full.notify_one();
                return Some(v);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Dequeue, waiting no later than `deadline`.  The batcher uses this
    /// to flush a partial batch when `max_wait` elapses before
    /// `max_batch` requests arrive.
    pub fn pop_deadline(&self, deadline: Instant) -> Popped<T> {
        let mut st = lock(&self.state);
        loop {
            if let Some(v) = st.buf.pop_front() {
                self.not_full.notify_one();
                return Popped::Item(v);
            }
            if st.closed {
                return Popped::Closed;
            }
            let now = Instant::now();
            let Some(left) = deadline.checked_duration_since(now).filter(|d| !d.is_zero()) else {
                return Popped::TimedOut;
            };
            let (guard, timeout) = self
                .not_empty
                .wait_timeout(st, left)
                .unwrap_or_else(|e| e.into_inner());
            st = guard;
            if timeout.timed_out() && st.buf.is_empty() && !st.closed {
                return Popped::TimedOut;
            }
        }
    }

    /// Close the queue: subsequent pushes fail, consumers drain what is
    /// already buffered, and every blocked thread wakes.  Idempotent.
    pub fn close(&self) {
        lock(&self.state).closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Whether [`close`](Self::close) has been called.
    pub fn is_closed(&self) -> bool {
        lock(&self.state).closed
    }

    /// Items currently buffered (a racy snapshot, for tests/telemetry).
    pub fn len(&self) -> usize {
        lock(&self.state).buf.len()
    }

    /// Whether the buffer is currently empty (racy snapshot).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// Oneshot
// ---------------------------------------------------------------------------

enum Slot<T> {
    /// No value yet; sender still alive.
    Pending,
    /// Value delivered, waiting for the receiver.
    Sent(T),
    /// Sender dropped without sending (request was abandoned).
    Hung,
}

struct OneshotInner<T> {
    slot: Mutex<Slot<T>>,
    cv: Condvar,
}

/// Sending half of a [`oneshot`] channel; consumed by
/// [`send`](OneshotSender::send).  Dropping it unsent wakes the receiver
/// with "no value" instead of deadlocking it — that is how a request
/// abandoned mid-shutdown resolves.
pub struct OneshotSender<T>(Option<Arc<OneshotInner<T>>>);

/// Receiving half of a [`oneshot`] channel; consumed by
/// [`recv`](OneshotReceiver::recv).
pub struct OneshotReceiver<T>(Arc<OneshotInner<T>>);

/// Create the per-request result channel: the worker resolves the
/// sender, the submitter blocks on the receiver.
pub fn oneshot<T>() -> (OneshotSender<T>, OneshotReceiver<T>) {
    let inner = Arc::new(OneshotInner { slot: Mutex::new(Slot::Pending), cv: Condvar::new() });
    (OneshotSender(Some(inner.clone())), OneshotReceiver(inner))
}

impl<T> OneshotSender<T> {
    /// Deliver the value and wake the receiver.
    pub fn send(mut self, v: T) {
        if let Some(inner) = self.0.take() {
            *lock(&inner.slot) = Slot::Sent(v);
            inner.cv.notify_all();
        }
    }
}

impl<T> Drop for OneshotSender<T> {
    fn drop(&mut self) {
        if let Some(inner) = self.0.take() {
            let mut slot = lock(&inner.slot);
            if matches!(*slot, Slot::Pending) {
                *slot = Slot::Hung;
                inner.cv.notify_all();
            }
        }
    }
}

impl<T> OneshotReceiver<T> {
    /// Block until the value arrives; `None` if the sender was dropped
    /// without sending.
    pub fn recv(self) -> Option<T> {
        let mut slot = lock(&self.0.slot);
        loop {
            match std::mem::replace(&mut *slot, Slot::Hung) {
                Slot::Sent(v) => return Some(v),
                Slot::Hung => return None,
                Slot::Pending => {
                    *slot = Slot::Pending;
                    slot = self.0.cv.wait(slot).unwrap_or_else(|e| e.into_inner());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order_preserved() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn push_blocks_at_capacity_until_pop() {
        let q = BoundedQueue::new(1);
        q.push(1).unwrap();
        let q2 = q.clone();
        let t = thread::spawn(move || q2.push(2).is_ok());
        // the producer is parked on not_full until we pop
        thread::sleep(Duration::from_millis(20));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some(1));
        assert!(t.join().unwrap());
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_rejects_pushes_but_drains_pops() {
        let q = BoundedQueue::new(4);
        q.push(7).unwrap();
        q.close();
        assert!(q.is_closed());
        assert_eq!(q.push(8), Err(8));
        // the buffered item survives close — draining shutdown
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn try_push_reports_full_and_closed_without_blocking() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert!(matches!(q.try_push(3), Err(TryPush::Full(3))));
        assert_eq!(q.pop(), Some(1));
        q.try_push(3).unwrap(); // room again after a pop
        q.close();
        assert!(matches!(q.try_push(4), Err(TryPush::Closed(4))));
        // the buffered items survive close — draining shutdown
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_blocked_popper() {
        let q: Arc<BoundedQueue<u32>> = BoundedQueue::new(4);
        let q2 = q.clone();
        let t = thread::spawn(move || q2.pop());
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(t.join().unwrap(), None);
    }

    #[test]
    fn pop_deadline_times_out_then_delivers() {
        let q: Arc<BoundedQueue<u32>> = BoundedQueue::new(4);
        let t0 = Instant::now();
        assert!(matches!(
            q.pop_deadline(t0 + Duration::from_millis(10)),
            Popped::TimedOut
        ));
        assert!(t0.elapsed() >= Duration::from_millis(10));
        q.push(3).unwrap();
        assert!(matches!(
            q.pop_deadline(Instant::now() + Duration::from_millis(10)),
            Popped::Item(3)
        ));
    }

    #[test]
    fn pop_deadline_reports_closed() {
        let q: Arc<BoundedQueue<u32>> = BoundedQueue::new(4);
        q.close();
        assert!(matches!(
            q.pop_deadline(Instant::now() + Duration::from_millis(5)),
            Popped::Closed
        ));
    }

    #[test]
    fn mpsc_under_contention_delivers_everything() {
        let q = BoundedQueue::new(4);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    for i in 0..50 {
                        q.push(p * 100 + i).unwrap();
                    }
                })
            })
            .collect();
        let mut got = Vec::new();
        for _ in 0..200 {
            got.push(q.pop().unwrap());
        }
        for t in producers {
            t.join().unwrap();
        }
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 200);
    }

    #[test]
    fn oneshot_delivers() {
        let (tx, rx) = oneshot();
        let t = thread::spawn(move || rx.recv());
        tx.send(42);
        assert_eq!(t.join().unwrap(), Some(42));
    }

    #[test]
    fn dropped_sender_resolves_receiver() {
        let (tx, rx) = oneshot::<u32>();
        drop(tx);
        assert_eq!(rx.recv(), None);
    }
}
