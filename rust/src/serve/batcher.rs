//! Dynamic micro-batcher: groups queued requests into batches of at most
//! `max_batch`, flushing early when `max_wait` elapses — whichever comes
//! first.
//!
//! The batching window opens when the *first* request of a batch is
//! popped, so a lone request waits at most `max_wait` before running,
//! while a busy queue fills `max_batch` immediately and never waits.
//! Requests are popped in FIFO order and batches are emitted in FIFO
//! order, so no request can be overtaken by one submitted after it
//! (fairness; completion order across a multi-worker pool may still
//! interleave, which per-request routing makes harmless).
//!
//! **Adaptive mode** (`--batch.adaptive`, [`BatchCfg::adaptive`]) keeps
//! both static bounds and adds an early-flush heuristic: an EWMA of the
//! observed inter-arrival gap estimates how long the next request is
//! likely to take; once the queue has been idle for a few multiples of
//! that estimate the burst is over and the partial batch flushes
//! immediately instead of sleeping out the rest of `max_wait`.  The
//! effective flush window is always within `[0, max_wait]`
//! ([`AdaptiveWindow::idle_wait`] clamps), so adaptive mode can only
//! *shorten* the wait a request pays — never starve it past the static
//! bound (property-tested below).

#![warn(missing_docs)]

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::queue::{BoundedQueue, Popped};

/// Batching knobs (`--batch.max` / `--batch.wait-ms` / `--batch.adaptive`
/// on the CLI).
#[derive(Clone, Copy, Debug)]
pub struct BatchCfg {
    /// Flush as soon as a batch holds this many requests.
    pub max_batch: usize,
    /// Flush a partial batch this long after its first request arrived.
    pub max_wait: Duration,
    /// Tune the flush window from the observed arrival rate (EWMA of
    /// inter-arrival gaps), bounded above by `max_wait`.
    pub adaptive: bool,
}

impl Default for BatchCfg {
    fn default() -> Self {
        BatchCfg { max_batch: 32, max_wait: Duration::from_millis(2), adaptive: false }
    }
}

/// Items the batcher can stamp with trace timestamps.  The no-op
/// defaults let plain payloads (tests, benches) flow through the same
/// loop as traced [`Request`](super::worker::Request)s.
pub trait BatchItem {
    /// The item was popped into a forming micro-batch.
    fn stamp_batched(&mut self, now: Instant) {
        let _ = now;
    }
    /// The item's micro-batch closed and is leaving for the worker pool.
    fn stamp_flushed(&mut self, now: Instant) {
        let _ = now;
    }
}

impl BatchItem for usize {}

/// EWMA-driven flush-window estimator for adaptive batching.
///
/// `observe_gap` feeds the gap between consecutive pops within a forming
/// batch; [`AdaptiveWindow::idle_wait`] answers "how long should the
/// batcher wait for one more request before concluding the burst is
/// over".  Invariant (property-tested): the answer is always within
/// `[0, max_wait]` — before any observation it *is* `max_wait`
/// (identical to static mode), and with observations it is
/// `clamp(GAP_MULT × ewma, IDLE_FLOOR..max_wait)`.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveWindow {
    ewma_gap_us: f64,
    observed: bool,
    max_wait: Duration,
}

/// EWMA smoothing factor for inter-arrival gaps.
const GAP_EWMA_ALPHA: f64 = 0.2;
/// Idle patience as a multiple of the estimated inter-arrival gap.
const GAP_MULT: f64 = 4.0;
/// Lower clamp on the idle patience, µs — below this the batcher would
/// burn CPU rechecking a queue the OS scheduler hasn't even woken a
/// producer into.
const IDLE_FLOOR_US: f64 = 50.0;

impl AdaptiveWindow {
    /// An estimator bounded above by `max_wait`.
    pub fn new(max_wait: Duration) -> AdaptiveWindow {
        AdaptiveWindow { ewma_gap_us: 0.0, observed: false, max_wait }
    }

    /// Feed one observed inter-arrival gap.
    pub fn observe_gap(&mut self, gap: Duration) {
        let us = gap.as_secs_f64() * 1e6;
        if self.observed {
            self.ewma_gap_us = GAP_EWMA_ALPHA * us + (1.0 - GAP_EWMA_ALPHA) * self.ewma_gap_us;
        } else {
            self.ewma_gap_us = us;
            self.observed = true;
        }
    }

    /// How long to wait for the next request before flushing a partial
    /// batch.  Always within `[0, max_wait]`.
    pub fn idle_wait(&self) -> Duration {
        if !self.observed {
            return self.max_wait;
        }
        let max_us = self.max_wait.as_secs_f64() * 1e6;
        let us = (GAP_MULT * self.ewma_gap_us).clamp(IDLE_FLOOR_US.min(max_us), max_us);
        Duration::from_secs_f64(us / 1e6)
    }
}

/// Run the batching loop until the request queue is closed and drained.
///
/// Every popped request is emitted in exactly one batch — including
/// during shutdown: close-then-drain semantics of [`BoundedQueue`] mean
/// the final partial batches still flow downstream before this returns.
/// The batch queue is closed on exit so the worker pool winds down after
/// draining it.
///
/// Items are stamped through [`BatchItem`] as they join a batch and
/// again (batch-wide) when it flushes, feeding the serve-path trace
/// spans (RFC 0006).
pub fn run<T: BatchItem>(
    requests: &Arc<BoundedQueue<T>>,
    batches: &Arc<BoundedQueue<Vec<T>>>,
    cfg: BatchCfg,
) {
    let max_batch = cfg.max_batch.max(1);
    let mut window = if cfg.adaptive { Some(AdaptiveWindow::new(cfg.max_wait)) } else { None };
    'serve: while let Some(mut first) = requests.pop() {
        let now = Instant::now();
        first.stamp_batched(now);
        // the static bound: a batch never flushes later than this
        let hard_deadline = now + cfg.max_wait;
        let mut last_pop = now;
        let mut batch = Vec::with_capacity(max_batch);
        batch.push(first);
        let mut drained = false;
        while batch.len() < max_batch {
            let deadline = match &window {
                Some(w) => hard_deadline.min(last_pop + w.idle_wait()),
                None => hard_deadline,
            };
            match requests.pop_deadline(deadline) {
                Popped::Item(mut v) => {
                    let now = Instant::now();
                    if let Some(w) = &mut window {
                        w.observe_gap(now.saturating_duration_since(last_pop));
                    }
                    last_pop = now;
                    v.stamp_batched(now);
                    batch.push(v);
                }
                // static: max_wait elapsed; adaptive: the burst ended
                // (or max_wait elapsed) — either way, flush
                Popped::TimedOut => break,
                Popped::Closed => {
                    drained = true;
                    break;
                }
            }
        }
        let flush = Instant::now();
        for v in &mut batch {
            v.stamp_flushed(flush);
        }
        if batches.push(batch).is_err() {
            // downstream gone (worker pool shut first): dropping the
            // requests resolves their oneshots as abandoned
            break 'serve;
        }
        if drained {
            break;
        }
    }
    batches.close();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use std::thread;

    type ReqQueue = Arc<BoundedQueue<usize>>;
    type BatchQueue = Arc<BoundedQueue<Vec<usize>>>;

    fn spawn_batcher(cfg: BatchCfg, cap: usize) -> (ReqQueue, BatchQueue, thread::JoinHandle<()>) {
        let requests = BoundedQueue::new(cap);
        let batches = BoundedQueue::new(cap);
        let (rq, bq) = (requests.clone(), batches.clone());
        let h = thread::spawn(move || run(&rq, &bq, cfg));
        (requests, batches, h)
    }

    #[test]
    fn full_batches_flush_in_fifo_order() {
        let cfg = BatchCfg { max_batch: 4, max_wait: Duration::from_secs(5), adaptive: false };
        let (requests, batches, h) = spawn_batcher(cfg, 64);
        for i in 0..8 {
            requests.push(i).unwrap();
        }
        // two full batches despite the long deadline — max_batch flushes
        assert_eq!(batches.pop(), Some(vec![0, 1, 2, 3]));
        assert_eq!(batches.pop(), Some(vec![4, 5, 6, 7]));
        requests.close();
        h.join().unwrap();
        assert_eq!(batches.pop(), None);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let cfg = BatchCfg { max_batch: 64, max_wait: Duration::from_millis(15), adaptive: false };
        let (requests, batches, h) = spawn_batcher(cfg, 64);
        let t0 = Instant::now();
        requests.push(1).unwrap();
        requests.push(2).unwrap();
        // far fewer than max_batch queued: only the deadline can flush
        assert_eq!(batches.pop(), Some(vec![1, 2]));
        assert!(t0.elapsed() >= Duration::from_millis(15));
        assert!(t0.elapsed() < Duration::from_secs(5), "deadline flush did not engage");
        requests.close();
        h.join().unwrap();
    }

    #[test]
    fn close_drains_pending_requests_into_final_batches() {
        let cfg = BatchCfg { max_batch: 4, max_wait: Duration::from_secs(5), adaptive: false };
        let requests = BoundedQueue::new(64);
        let batches = BoundedQueue::new(64);
        for i in 0..10 {
            requests.push(i).unwrap();
        }
        requests.close();
        // batcher started after close: everything buffered still flows
        run(&requests, &batches, cfg);
        let mut got = Vec::new();
        while let Some(b) = batches.pop() {
            assert!(b.len() <= 4);
            got.extend(b);
        }
        assert_eq!(got, (0..10).collect::<Vec<_>>());
        assert!(batches.is_closed());
    }

    #[test]
    fn exits_when_downstream_closes_first() {
        let cfg = BatchCfg { max_batch: 2, max_wait: Duration::from_millis(1), adaptive: false };
        let (requests, batches, h) = spawn_batcher(cfg, 8);
        batches.close();
        requests.push(1).unwrap();
        h.join().unwrap();
    }

    // -- adaptive-mode property tests ------------------------------------

    /// Property: for any gap stream, the flush window stays in
    /// `[0, max_wait]` — adaptive mode can only shorten the static wait.
    #[test]
    fn adaptive_window_always_within_static_bound() {
        let mut rng = Pcg64::new(42);
        for max_wait_us in [0u64, 10, 50, 2_000, 500_000] {
            let max_wait = Duration::from_micros(max_wait_us);
            let mut w = AdaptiveWindow::new(max_wait);
            assert_eq!(w.idle_wait(), max_wait, "uninitialized EWMA must behave statically");
            for _ in 0..500 {
                // gaps spanning ns to seconds, well beyond max_wait
                let gap = Duration::from_micros(rng.below(2_000_000) as u64);
                w.observe_gap(gap);
                let wait = w.idle_wait();
                assert!(wait <= max_wait, "idle_wait {wait:?} exceeds max_wait {max_wait:?}");
            }
        }
    }

    /// Property: adaptive mode never emits a batch above `max_batch`,
    /// even under a flood that keeps the EWMA near zero.
    #[test]
    fn adaptive_never_exceeds_max_batch() {
        let cfg = BatchCfg { max_batch: 4, max_wait: Duration::from_millis(50), adaptive: true };
        let (requests, batches, h) = spawn_batcher(cfg, 256);
        for i in 0..64 {
            requests.push(i).unwrap();
        }
        requests.close();
        let mut got = Vec::new();
        while let Some(b) = batches.pop() {
            assert!(!b.is_empty() && b.len() <= 4, "batch of {} exceeds max_batch", b.len());
            got.extend(b);
        }
        assert_eq!(got, (0..64).collect::<Vec<_>>(), "FIFO order broken");
        h.join().unwrap();
    }

    /// Property: a steady low-rate stream is never starved longer than
    /// the static bound — every lone request flushes within `max_wait`
    /// (plus scheduling slack), exactly like static mode (PR 4
    /// semantics).
    #[test]
    fn adaptive_low_rate_stream_not_starved_past_static_bound() {
        let max_wait = Duration::from_millis(40);
        let cfg = BatchCfg { max_batch: 32, max_wait, adaptive: true };
        let (requests, batches, h) = spawn_batcher(cfg, 64);
        for i in 0..3 {
            let t0 = Instant::now();
            requests.push(i).unwrap();
            assert_eq!(batches.pop(), Some(vec![i]));
            let waited = t0.elapsed();
            // static bound + generous scheduling slack for busy CI hosts
            assert!(waited < max_wait + Duration::from_millis(200), "starved: {waited:?}");
        }
        requests.close();
        h.join().unwrap();
    }

    /// The adaptive win: once a burst's arrival cadence is observed, a
    /// partial batch flushes a few EWMA-gaps after the burst ends
    /// instead of sleeping out the full static window.
    #[test]
    fn adaptive_flushes_partial_batch_well_before_max_wait() {
        let max_wait = Duration::from_millis(800);
        let cfg = BatchCfg { max_batch: 32, max_wait, adaptive: true };
        let requests: ReqQueue = BoundedQueue::new(64);
        let batches: BatchQueue = BoundedQueue::new(64);
        // a burst of 6 is already queued when the batcher starts: the
        // intra-burst pop gaps (~µs) initialize the EWMA
        for i in 0..6 {
            requests.push(i).unwrap();
        }
        let (rq, bq) = (requests.clone(), batches.clone());
        let h = thread::spawn(move || run(&rq, &bq, cfg));
        let t0 = Instant::now();
        let batch = batches.pop().expect("burst batch");
        let waited = t0.elapsed();
        assert_eq!(batch, vec![0, 1, 2, 3, 4, 5]);
        assert!(
            waited < max_wait / 2,
            "adaptive flush took {waited:?}, expected well under {max_wait:?}"
        );
        requests.close();
        h.join().unwrap();
    }
}
