//! Dynamic micro-batcher: groups queued requests into batches of at most
//! `max_batch`, flushing early when `max_wait` elapses — whichever comes
//! first.
//!
//! The batching window opens when the *first* request of a batch is
//! popped, so a lone request waits at most `max_wait` before running,
//! while a busy queue fills `max_batch` immediately and never waits.
//! Requests are popped in FIFO order and batches are emitted in FIFO
//! order, so no request can be overtaken by one submitted after it
//! (fairness; completion order across a multi-worker pool may still
//! interleave, which per-request routing makes harmless).

#![warn(missing_docs)]

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::queue::{BoundedQueue, Popped};

/// Batching knobs (`--batch.max` / `--batch.wait-ms` on the CLI).
#[derive(Clone, Copy, Debug)]
pub struct BatchCfg {
    /// Flush as soon as a batch holds this many requests.
    pub max_batch: usize,
    /// Flush a partial batch this long after its first request arrived.
    pub max_wait: Duration,
}

impl Default for BatchCfg {
    fn default() -> Self {
        BatchCfg { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// Run the batching loop until the request queue is closed and drained.
///
/// Every popped request is emitted in exactly one batch — including
/// during shutdown: close-then-drain semantics of [`BoundedQueue`] mean
/// the final partial batches still flow downstream before this returns.
/// The batch queue is closed on exit so the worker pool winds down after
/// draining it.
pub fn run<T>(requests: &Arc<BoundedQueue<T>>, batches: &Arc<BoundedQueue<Vec<T>>>, cfg: BatchCfg) {
    let max_batch = cfg.max_batch.max(1);
    'serve: while let Some(first) = requests.pop() {
        let deadline = Instant::now() + cfg.max_wait;
        let mut batch = Vec::with_capacity(max_batch);
        batch.push(first);
        let mut drained = false;
        while batch.len() < max_batch {
            match requests.pop_deadline(deadline) {
                Popped::Item(v) => batch.push(v),
                Popped::TimedOut => break,
                Popped::Closed => {
                    drained = true;
                    break;
                }
            }
        }
        if batches.push(batch).is_err() {
            // downstream gone (worker pool shut first): dropping the
            // requests resolves their oneshots as abandoned
            break 'serve;
        }
        if drained {
            break;
        }
    }
    batches.close();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    type ReqQueue = Arc<BoundedQueue<usize>>;
    type BatchQueue = Arc<BoundedQueue<Vec<usize>>>;

    fn spawn_batcher(cfg: BatchCfg, cap: usize) -> (ReqQueue, BatchQueue, thread::JoinHandle<()>) {
        let requests = BoundedQueue::new(cap);
        let batches = BoundedQueue::new(cap);
        let (rq, bq) = (requests.clone(), batches.clone());
        let h = thread::spawn(move || run(&rq, &bq, cfg));
        (requests, batches, h)
    }

    #[test]
    fn full_batches_flush_in_fifo_order() {
        let cfg = BatchCfg { max_batch: 4, max_wait: Duration::from_secs(5) };
        let (requests, batches, h) = spawn_batcher(cfg, 64);
        for i in 0..8 {
            requests.push(i).unwrap();
        }
        // two full batches despite the long deadline — max_batch flushes
        assert_eq!(batches.pop(), Some(vec![0, 1, 2, 3]));
        assert_eq!(batches.pop(), Some(vec![4, 5, 6, 7]));
        requests.close();
        h.join().unwrap();
        assert_eq!(batches.pop(), None);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let cfg = BatchCfg { max_batch: 64, max_wait: Duration::from_millis(15) };
        let (requests, batches, h) = spawn_batcher(cfg, 64);
        let t0 = Instant::now();
        requests.push(1).unwrap();
        requests.push(2).unwrap();
        // far fewer than max_batch queued: only the deadline can flush
        assert_eq!(batches.pop(), Some(vec![1, 2]));
        assert!(t0.elapsed() >= Duration::from_millis(15));
        assert!(t0.elapsed() < Duration::from_secs(5), "deadline flush did not engage");
        requests.close();
        h.join().unwrap();
    }

    #[test]
    fn close_drains_pending_requests_into_final_batches() {
        let cfg = BatchCfg { max_batch: 4, max_wait: Duration::from_secs(5) };
        let requests = BoundedQueue::new(64);
        let batches = BoundedQueue::new(64);
        for i in 0..10 {
            requests.push(i).unwrap();
        }
        requests.close();
        // batcher started after close: everything buffered still flows
        run(&requests, &batches, cfg);
        let mut got = Vec::new();
        while let Some(b) = batches.pop() {
            assert!(b.len() <= 4);
            got.extend(b);
        }
        assert_eq!(got, (0..10).collect::<Vec<_>>());
        assert!(batches.is_closed());
    }

    #[test]
    fn exits_when_downstream_closes_first() {
        let cfg = BatchCfg { max_batch: 2, max_wait: Duration::from_millis(1) };
        let (requests, batches, h) = spawn_batcher(cfg, 8);
        batches.close();
        requests.push(1).unwrap();
        h.join().unwrap();
    }
}
