//! Structured serve-path tracing (RFC 0006): per-request spans, rolling
//! latency histograms, and pluggable trace subscribers.
//!
//! Every [`Request`](super::worker::Request) carries a [`Span`] — a
//! `Copy` bundle of monotonic [`Instant`] stamps set as the request
//! moves queued→admitted→batched→flushed→executed; the reply-routing
//! stamp is taken batch-wide by the worker.  After a batch's replies are
//! sent, the worker publishes the batch's spans to its lane's
//! [`LaneTrace`], which (a) folds per-stage durations into rolling
//! [`RollingHist`] percentile estimators (the live `{"stats":true}` /
//! bench surface) and (b) fans a [`TraceEvent`] per request out to every
//! registered [`TraceSubscriber`].
//!
//! The steady-state serve path stays allocation-free with tracing
//! enabled (`rust/tests/workspace_alloc.rs` asserts this): spans are
//! inline `Copy` data, histograms are fixed-size bucket arrays behind
//! one per-lane mutex, and the bundled [`JsonlTraceRecorder`] buffers
//! events in a preallocated ring it only formats and writes at flush
//! boundaries.

#![warn(missing_docs)]

use std::io::Write;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::{anyhow, Result};

/// Monotonic per-request timestamps, stamped as the request crosses each
/// serve-path stage.  `Copy` and inline in the request so stamping never
/// allocates.  All stamps default to the creation instant, so a span
/// that skips a stage (e.g. a rejected request) still has ordered,
/// non-panicking durations.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    /// Submission entered the registry (before validation).
    pub queued: Instant,
    /// Validation passed; the request entered its lane's intake queue.
    pub admitted: Instant,
    /// The batcher popped the request into a forming micro-batch.
    pub batched: Instant,
    /// The micro-batch closed and was handed to the worker pool.
    pub flushed: Instant,
}

impl Span {
    /// Open a span at the current instant (all stamps initialized to now).
    pub fn begin() -> Span {
        let now = Instant::now();
        Span { queued: now, admitted: now, batched: now, flushed: now }
    }
}

/// One request's trace record, handed to [`TraceSubscriber::on_event`]
/// after its reply was routed.  All times are microsecond offsets from
/// the owning registry's epoch (a single monotonic clock shared by every
/// lane, so multi-model traces interleave on one axis).
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent<'a> {
    /// Lane (model) name.
    pub model: &'a Arc<str>,
    /// Offset of [`Span::queued`].
    pub queued_us: u64,
    /// Offset of [`Span::admitted`].
    pub admitted_us: u64,
    /// Offset of [`Span::batched`].
    pub batched_us: u64,
    /// Offset of [`Span::flushed`].
    pub flushed_us: u64,
    /// Offset of the engine forward completing (batch-wide).
    pub executed_us: u64,
    /// Offset of the reply resolving the request's oneshot (batch-wide).
    pub routed_us: u64,
    /// How many requests shared this event's micro-batch.
    pub batch_len: u32,
    /// Whether the reply carried logits (`false` = engine error).
    pub ok: bool,
}

/// A sink for [`TraceEvent`]s.  Called on the worker thread after each
/// batch's replies were sent; implementations must not allocate per
/// event on the steady path — buffer inline and allocate only in
/// [`TraceSubscriber::flush`] (the contract `workspace_alloc.rs`
/// enforces for the bundled recorder).
pub trait TraceSubscriber: Send + Sync {
    /// Observe one routed request.
    fn on_event(&self, ev: &TraceEvent<'_>);
    /// Drain any buffered events to the underlying sink.  Called at
    /// registry shutdown/retire and whenever an implementation's buffer
    /// fills.
    fn flush(&self) {}
}

// ---------------------------------------------------------------------------
// Rolling log-bucketed histogram
// ---------------------------------------------------------------------------

/// Bucket count: log-linear with 8 sub-buckets per octave (3 mantissa
/// bits), exact below 8µs, covering ~2.3 hours before clamping — worst
/// relative quantization error 12.5%, midpoint estimate within ~7%.
const HIST_BUCKETS: usize = 256;

/// Rolling p50/p95/p99 latency estimator over log-spaced microsecond
/// buckets.  Two windows (current + previous) roll by event count:
/// percentiles always reflect between `window` and `2×window` recent
/// samples, and a burst from an hour ago cannot haunt the live stats.
/// Recording is allocation-free; the bucket arrays are allocated once
/// at construction.
#[derive(Clone, Debug)]
pub struct RollingHist {
    cur: Vec<u32>,
    prev: Vec<u32>,
    cur_n: u32,
    window: u32,
}

fn bucket_of(us: u64) -> usize {
    if us < 8 {
        return us as usize;
    }
    let msb = 63 - us.leading_zeros() as usize;
    let idx = (msb - 3) * 8 + ((us >> (msb - 3)) as usize & 0xf);
    idx.min(HIST_BUCKETS - 1)
}

/// Inclusive lower edge of a bucket, in µs.
fn bucket_floor(idx: usize) -> u64 {
    if idx < 8 {
        idx as u64
    } else {
        let shift = idx / 8 - 1;
        ((8 + idx % 8) as u64) << shift
    }
}

/// Midpoint estimate for a bucket, in µs.
fn bucket_mid(idx: usize) -> f64 {
    if idx < 8 {
        idx as f64
    } else {
        let width = 1u64 << (idx / 8 - 1);
        bucket_floor(idx) as f64 + width as f64 / 2.0
    }
}

impl RollingHist {
    /// A histogram rolling every `window` recorded samples.
    pub fn new(window: u32) -> RollingHist {
        RollingHist {
            cur: vec![0; HIST_BUCKETS],
            prev: vec![0; HIST_BUCKETS],
            cur_n: 0,
            window: window.max(1),
        }
    }

    /// Record one duration (µs).  Never allocates; rolls the window in
    /// place when `window` samples have accumulated.
    pub fn record(&mut self, us: u64) {
        self.cur[bucket_of(us)] += 1;
        self.cur_n += 1;
        if self.cur_n >= self.window {
            std::mem::swap(&mut self.cur, &mut self.prev);
            self.cur.iter_mut().for_each(|c| *c = 0);
            self.cur_n = 0;
        }
    }

    /// Samples currently contributing to percentile estimates (current +
    /// previous window).
    pub fn len(&self) -> u64 {
        self.cur.iter().chain(self.prev.iter()).map(|&c| c as u64).sum()
    }

    /// True when no samples have been recorded in the live windows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Nearest-rank percentile estimate in µs over the live windows
    /// (`q` in `[0, 1]`).  Returns the matched bucket's midpoint —
    /// within ~7% of the exact sorted-sample percentile — or `0.0` for
    /// an empty histogram.
    pub fn percentile(&self, q: f64) -> f64 {
        let total = self.len();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for i in 0..HIST_BUCKETS {
            seen += (self.cur[i] + self.prev[i]) as u64;
            if seen >= rank {
                return bucket_mid(i);
            }
        }
        bucket_mid(HIST_BUCKETS - 1)
    }
}

// ---------------------------------------------------------------------------
// Per-lane aggregation
// ---------------------------------------------------------------------------

/// p50/p95/p99 snapshot for one serve stage, in µs.
#[derive(Clone, Copy, Debug, Default)]
pub struct StagePcts {
    /// Median.
    pub p50_us: f64,
    /// 95th percentile.
    pub p95_us: f64,
    /// 99th percentile.
    pub p99_us: f64,
}

/// Live trace snapshot for one lane, surfaced through
/// [`ModelStats`](super::registry::ModelStats) and the inline
/// `{"stats":true}` reply.
#[derive(Clone, Debug)]
pub struct TraceStats {
    /// Requests published (routed replies, ok or failed).
    pub events: u64,
    /// Micro-batches executed.
    pub batches: u64,
    /// EWMA of executed batch size, in requests.
    pub mean_batch: f64,
    /// Intake wait: queued → batched.
    pub queue: StagePcts,
    /// Batch formation wait: batched → flushed (the adaptive batcher's
    /// target).
    pub batch: StagePcts,
    /// Stack + engine forward + split: flushed → executed.
    pub exec: StagePcts,
    /// End to end: queued → routed.
    pub total: StagePcts,
}

/// EWMA smoothing for the batch-fill estimate.
const FILL_EWMA_ALPHA: f64 = 0.2;
/// Default histogram window (samples per roll).
const DEFAULT_HIST_WINDOW: u32 = 4096;

struct LaneMetrics {
    queue: RollingHist,
    batch: RollingHist,
    exec: RollingHist,
    total: RollingHist,
    mean_batch: f64,
    events: u64,
    batches: u64,
}

/// Per-lane trace aggregation point: rolling per-stage histograms plus
/// the registry-wide subscriber fan-out.  One per
/// [`ModelEntry`](super::registry::Registry), shared with that lane's
/// workers.
pub struct LaneTrace {
    model: Arc<str>,
    epoch: Instant,
    metrics: Mutex<LaneMetrics>,
    subs: Vec<Arc<dyn TraceSubscriber>>,
    enabled: bool,
}

impl LaneTrace {
    /// A live trace for `model`, publishing to `subs`.  `epoch` is the
    /// registry's shared clock origin for event offsets.
    pub fn new(model: Arc<str>, epoch: Instant, subs: Vec<Arc<dyn TraceSubscriber>>) -> LaneTrace {
        LaneTrace {
            model,
            epoch,
            metrics: Mutex::new(LaneMetrics {
                queue: RollingHist::new(DEFAULT_HIST_WINDOW),
                batch: RollingHist::new(DEFAULT_HIST_WINDOW),
                exec: RollingHist::new(DEFAULT_HIST_WINDOW),
                total: RollingHist::new(DEFAULT_HIST_WINDOW),
                mean_batch: 0.0,
                events: 0,
                batches: 0,
            }),
            subs,
            enabled: true,
        }
    }

    /// A no-op trace: `publish_batch` returns immediately.  Used by the
    /// single-engine test shims and as the A/B baseline in the
    /// zero-allocation test.
    pub fn disabled(model: Arc<str>) -> LaneTrace {
        let mut t = LaneTrace::new(model, Instant::now(), Vec::new());
        t.enabled = false;
        t
    }

    /// Lane name.
    pub fn model(&self) -> &Arc<str> {
        &self.model
    }

    /// Publish one executed micro-batch: fold every span's stage
    /// durations into the rolling histograms (one lock per batch), then
    /// fan events out to subscribers.  `executed`/`routed` are batch-wide
    /// stamps taken by the worker.  Allocation-free on the steady path.
    pub fn publish_batch(&self, spans: &[Span], executed: Instant, routed: Instant, ok: bool) {
        if !self.enabled || spans.is_empty() {
            return;
        }
        {
            let mut m = self.metrics.lock().unwrap_or_else(|p| p.into_inner());
            for s in spans {
                m.queue.record(dur_us(s.queued, s.batched));
                m.batch.record(dur_us(s.batched, s.flushed));
                m.exec.record(dur_us(s.flushed, executed));
                m.total.record(dur_us(s.queued, routed));
            }
            m.events += spans.len() as u64;
            m.batches += 1;
            let b = spans.len() as f64;
            m.mean_batch = if m.batches == 1 {
                b
            } else {
                FILL_EWMA_ALPHA * b + (1.0 - FILL_EWMA_ALPHA) * m.mean_batch
            };
        }
        if self.subs.is_empty() {
            return;
        }
        let batch_len = spans.len() as u32;
        for s in spans {
            let ev = TraceEvent {
                model: &self.model,
                queued_us: off_us(self.epoch, s.queued),
                admitted_us: off_us(self.epoch, s.admitted),
                batched_us: off_us(self.epoch, s.batched),
                flushed_us: off_us(self.epoch, s.flushed),
                executed_us: off_us(self.epoch, executed),
                routed_us: off_us(self.epoch, routed),
                batch_len,
                ok,
            };
            for sub in &self.subs {
                sub.on_event(&ev);
            }
        }
    }

    /// Snapshot the lane's live percentiles and counters.
    pub fn stats(&self) -> TraceStats {
        let m = self.metrics.lock().unwrap_or_else(|p| p.into_inner());
        let pcts = |h: &RollingHist| StagePcts {
            p50_us: h.percentile(0.50),
            p95_us: h.percentile(0.95),
            p99_us: h.percentile(0.99),
        };
        TraceStats {
            events: m.events,
            batches: m.batches,
            mean_batch: m.mean_batch,
            queue: pcts(&m.queue),
            batch: pcts(&m.batch),
            exec: pcts(&m.exec),
            total: pcts(&m.total),
        }
    }
}

fn dur_us(from: Instant, to: Instant) -> u64 {
    to.saturating_duration_since(from).as_micros() as u64
}

fn off_us(epoch: Instant, t: Instant) -> u64 {
    t.saturating_duration_since(epoch).as_micros() as u64
}

// ---------------------------------------------------------------------------
// JSONL trace recorder
// ---------------------------------------------------------------------------

/// Owned copy of a [`TraceEvent`] buffered between flushes.  The model
/// handle is an `Arc` clone — no allocation on the record path.
struct BufEvent {
    model: Arc<str>,
    queued_us: u64,
    queue_us: u64,
    batch_us: u64,
    exec_us: u64,
    total_us: u64,
    batch_len: u32,
    ok: bool,
}

struct RecInner {
    buf: Vec<BufEvent>,
    out: Box<dyn Write + Send>,
}

/// A [`TraceSubscriber`] writing one JSON object per event (RFC 0006
/// trace schema) to an arbitrary sink.  Events accumulate in a
/// preallocated buffer; formatting and I/O happen only when the buffer
/// fills or on [`TraceSubscriber::flush`] — so with a buffer larger than
/// the measurement window the steady serve path stays allocation-free.
pub struct JsonlTraceRecorder {
    inner: Mutex<RecInner>,
    cap: usize,
}

impl JsonlTraceRecorder {
    /// Record to `out`, buffering up to `cap` events between writes.
    pub fn to_writer(out: Box<dyn Write + Send>, cap: usize) -> JsonlTraceRecorder {
        let cap = cap.max(1);
        let inner = Mutex::new(RecInner { buf: Vec::with_capacity(cap), out });
        JsonlTraceRecorder { inner, cap }
    }

    /// Record to a file at `path` (truncating), with the default 4096
    /// event buffer.
    pub fn create(path: &str) -> Result<JsonlTraceRecorder> {
        let f = std::fs::File::create(path)
            .map_err(|e| anyhow!("trace recorder: cannot create {path}: {e}"))?;
        Ok(JsonlTraceRecorder::to_writer(Box::new(std::io::BufWriter::new(f)), 4096))
    }

    fn flush_locked(inner: &mut RecInner) {
        let mut line = String::new();
        for ev in inner.buf.drain(..) {
            line.clear();
            line.push_str("{\"t_us\":");
            push_u64(&mut line, ev.queued_us);
            line.push_str(",\"model\":\"");
            line.push_str(&ev.model);
            line.push_str("\",\"queue_us\":");
            push_u64(&mut line, ev.queue_us);
            line.push_str(",\"batch_us\":");
            push_u64(&mut line, ev.batch_us);
            line.push_str(",\"exec_us\":");
            push_u64(&mut line, ev.exec_us);
            line.push_str(",\"total_us\":");
            push_u64(&mut line, ev.total_us);
            line.push_str(",\"batch_len\":");
            push_u64(&mut line, ev.batch_len as u64);
            line.push_str(",\"ok\":");
            line.push_str(if ev.ok { "true" } else { "false" });
            line.push_str("}\n");
            let _ = inner.out.write_all(line.as_bytes());
        }
        let _ = inner.out.flush();
    }
}

fn push_u64(s: &mut String, v: u64) {
    use std::fmt::Write as _;
    let _ = write!(s, "{v}");
}

impl TraceSubscriber for JsonlTraceRecorder {
    fn on_event(&self, ev: &TraceEvent<'_>) {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        inner.buf.push(BufEvent {
            model: ev.model.clone(),
            queued_us: ev.queued_us,
            queue_us: ev.batched_us.saturating_sub(ev.queued_us),
            batch_us: ev.flushed_us.saturating_sub(ev.batched_us),
            exec_us: ev.executed_us.saturating_sub(ev.flushed_us),
            total_us: ev.routed_us.saturating_sub(ev.queued_us),
            batch_len: ev.batch_len,
            ok: ev.ok,
        });
        if inner.buf.len() >= self.cap {
            JsonlTraceRecorder::flush_locked(&mut inner);
        }
    }

    fn flush(&self) {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        JsonlTraceRecorder::flush_locked(&mut inner);
    }
}

impl Drop for JsonlTraceRecorder {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn exact_percentile(sorted: &[u64], q: f64) -> f64 {
        let rank = ((q * sorted.len() as f64).ceil() as usize).max(1);
        sorted[rank - 1] as f64
    }

    fn assert_close(est: f64, exact: f64, what: &str) {
        let tol = (exact * 0.08).max(1.0);
        assert!((est - exact).abs() <= tol, "{what}: estimate {est} vs exact {exact} (tol {tol})");
    }

    fn check_stream(samples: &[u64], what: &str) {
        let mut h = RollingHist::new(u32::MAX);
        for &s in samples {
            h.record(s);
        }
        let mut sorted = samples.to_vec();
        sorted.sort_unstable();
        for q in [0.50, 0.95, 0.99] {
            assert_close(h.percentile(q), exact_percentile(&sorted, q), &format!("{what} p{q}"));
        }
    }

    #[test]
    fn percentiles_match_exact_on_uniform_stream() {
        let mut rng = Pcg64::new(11);
        let samples: Vec<u64> = (0..5000).map(|_| rng.below(20_000) as u64 + 1).collect();
        check_stream(&samples, "uniform");
    }

    #[test]
    fn percentiles_match_exact_on_bimodal_stream() {
        let mut rng = Pcg64::new(23);
        let samples: Vec<u64> = (0..5000)
            .map(|_| {
                if rng.below(10) < 8 {
                    rng.below(200) as u64 + 50 // fast mode ~50-250µs
                } else {
                    rng.below(5_000) as u64 + 20_000 // slow mode ~20-25ms
                }
            })
            .collect();
        check_stream(&samples, "bimodal");
    }

    #[test]
    fn single_sample_and_empty() {
        let mut h = RollingHist::new(16);
        assert!(h.is_empty());
        assert_eq!(h.percentile(0.50), 0.0);
        assert_eq!(h.percentile(0.99), 0.0);
        h.record(1234);
        for q in [0.0, 0.5, 1.0] {
            assert_close(h.percentile(q), 1234.0, "single sample");
        }
        assert_eq!(h.len(), 1);
    }

    #[test]
    fn window_roll_forgets_old_samples() {
        // window 8: percentiles span the current + previous windows only
        let mut h = RollingHist::new(8);
        for _ in 0..8 {
            h.record(10); // fills window 1, rolls into `prev`
        }
        assert_close(h.percentile(0.50), 10.0, "after first window");
        for _ in 0..8 {
            h.record(100_000); // window 2 rolls; window 1 is dropped
        }
        // live = prev(100_000 ×8) + cur(empty): the 10µs era is gone
        assert_close(h.percentile(0.50), 100_000.0, "old era evicted");
        assert_eq!(h.len(), 8);
        // mixed live windows still merge
        for _ in 0..4 {
            h.record(10);
        }
        assert_close(h.percentile(0.99), 100_000.0, "slow tail still visible");
        assert_close(h.percentile(0.25), 10.0, "fresh fast samples visible");
    }

    #[test]
    fn buckets_are_monotonic_and_invertible() {
        let mut last = 0;
        for v in 0..100_000u64 {
            let b = bucket_of(v);
            assert!(b >= last, "bucket_of not monotonic at {v}");
            last = b;
            let (lo, mid) = (bucket_floor(b), bucket_mid(b));
            assert!(lo <= v, "floor {lo} above value {v}");
            let rel = (mid - v as f64).abs() / (v.max(1) as f64);
            assert!(rel <= 0.07 || (mid - v as f64).abs() <= 1.0, "bucket error {rel} at {v}");
        }
    }

    #[test]
    fn lane_trace_aggregates_batches() {
        use std::time::Duration;
        let epoch = Instant::now();
        let trace = LaneTrace::new(Arc::from("m"), epoch, Vec::new());
        let mut span = Span::begin();
        span.batched = span.queued + Duration::from_micros(100);
        span.flushed = span.queued + Duration::from_micros(300);
        let executed = span.queued + Duration::from_micros(900);
        let routed = span.queued + Duration::from_micros(1000);
        trace.publish_batch(&[span, span], executed, routed, true);
        let s = trace.stats();
        assert_eq!(s.events, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch, 2.0);
        assert_close(s.queue.p50_us, 100.0, "queue stage");
        assert_close(s.batch.p50_us, 200.0, "batch stage");
        assert_close(s.exec.p50_us, 600.0, "exec stage");
        assert_close(s.total.p99_us, 1000.0, "total");
    }

    #[test]
    fn disabled_trace_publishes_nothing() {
        let trace = LaneTrace::disabled(Arc::from("m"));
        let span = Span::begin();
        trace.publish_batch(&[span], Instant::now(), Instant::now(), true);
        let s = trace.stats();
        assert_eq!(s.events, 0);
        assert!(trace.model().as_ref() == "m");
    }

    #[test]
    fn jsonl_recorder_formats_events_at_flush() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct SharedBuf(Arc<Mutex<Vec<u8>>>, Arc<AtomicUsize>);
        impl Write for SharedBuf {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.1.fetch_add(1, Ordering::SeqCst);
                self.0.lock().unwrap().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let sink = Arc::new(Mutex::new(Vec::new()));
        let writes = Arc::new(AtomicUsize::new(0));
        let sink_w = Box::new(SharedBuf(sink.clone(), writes.clone()));
        let rec = JsonlTraceRecorder::to_writer(sink_w, 3);
        let model: Arc<str> = Arc::from("mlp");
        let ev = |t: u64| TraceEvent {
            model: &model,
            queued_us: t,
            admitted_us: t + 1,
            batched_us: t + 10,
            flushed_us: t + 30,
            executed_us: t + 90,
            routed_us: t + 100,
            batch_len: 2,
            ok: true,
        };
        rec.on_event(&ev(0));
        rec.on_event(&ev(500));
        assert_eq!(writes.load(Ordering::SeqCst), 0, "no I/O before the buffer fills");
        rec.on_event(&ev(900)); // cap = 3 → flush boundary
        assert!(writes.load(Ordering::SeqCst) > 0);
        rec.flush();
        let text = String::from_utf8(sink.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let doc = crate::json::Json::parse(lines[1]).unwrap();
        assert_eq!(doc.get("model").unwrap().str().unwrap(), "mlp");
        assert_eq!(doc.get("t_us").unwrap().usize().unwrap(), 500);
        assert_eq!(doc.get("queue_us").unwrap().usize().unwrap(), 10);
        assert_eq!(doc.get("batch_us").unwrap().usize().unwrap(), 20);
        assert_eq!(doc.get("exec_us").unwrap().usize().unwrap(), 60);
        assert_eq!(doc.get("total_us").unwrap().usize().unwrap(), 100);
        assert_eq!(doc.get("batch_len").unwrap().usize().unwrap(), 2);
    }
}
