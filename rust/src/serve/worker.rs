//! Worker pool: turns micro-batches into answers.
//!
//! Each worker pops one `Vec<Request>` at a time, stacks the per-example
//! inputs into a single batched tensor, runs **one** engine forward over
//! it (amortizing the `u8×i8→i32` GEMMs across the whole batch — the
//! point of micro-batching), splits the logits back per example, and
//! resolves each request's oneshot.  Per-example logits are *batch
//! invariant*: every kernel on the serving path (integer GEMM, im2col
//! conv, relu, pooling, layernorm, per-sequence attention, residual add)
//! computes each example independently with a fixed reduction order, so
//! a request answered inside a batch of 64 carries bit-identical logits
//! to the same example served alone (`rust/tests/serve.rs` asserts
//! this against `--exec int8` eval).

#![warn(missing_docs)]

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::backend::Value;
use crate::coordinator::binder::{bind_inputs, BindCtx};
use crate::data::Batch;
use crate::error::{anyhow, bail, Result};
use crate::exec::Workspace;
use crate::graph::{GraphStep, InputKind, Layer, LayerGraph, StepId, StepKind};
use crate::lower::QuantizedGraph;
use crate::model::{ParamStore, QParamStore, StateStore};
use crate::tensor::{ITensor, Tensor};

use super::batcher::BatchItem;
use super::queue::{BoundedQueue, OneshotSender};
use super::registry::{EngineSlot, Reply};
use super::trace::{LaneTrace, Span};

/// One queued inference request: a single example plus the channel its
/// reply (logits + serving identity, or error) is routed back through.
pub struct Request {
    /// One example in the engine's input domain: f32 `[C, H, H]` images
    /// or i32 `[T]` token ids — no batch dimension; the batcher adds it.
    pub input: Value,
    /// Resolved by the worker that executes this request's batch.
    pub tx: OneshotSender<Result<Reply>>,
    /// Trace stamps (RFC 0006), carried inline so stamping never
    /// allocates.  Opened at submission; the batcher and worker fill in
    /// the later stages.
    pub span: Span,
}

impl BatchItem for Request {
    fn stamp_batched(&mut self, now: Instant) {
        self.span.batched = now;
    }

    fn stamp_flushed(&mut self, now: Instant) {
        self.span.flushed = now;
    }
}

/// A batch-flexible forward engine the serving runtime can pool workers
/// over.  Implemented by the lowered int8 [`QuantizedGraph`] (the
/// deployed arithmetic, `--exec int8`) and by [`FloatEngine`] (the
/// fake-quant f32 reference, `--exec f32` — the A/B baseline).
pub trait Engine: Send + Sync {
    /// Model name, for logs and error messages.
    fn model(&self) -> &str;
    /// Input domain (image geometry or token sequence length).
    fn input(&self) -> InputKind;
    /// Trailing logits dimension (classes or vocab).
    fn classes(&self) -> usize;
    /// Vocabulary size for token models (`None` for image models) —
    /// lets submission reject out-of-range ids *before* they join a
    /// batch, where they would fail every co-batched request.
    fn vocab(&self) -> Option<usize>;
    /// Run one batched forward to logits, consuming the input.
    fn forward_batch(&self, x: Value) -> Result<Tensor>;

    /// Run one batched forward over a caller-owned [`Workspace`] — the
    /// worker hot path.  The returned tensor's buffers may be pooled;
    /// give them back to `ws` after splitting.  Engines without a
    /// planned executor fall back to [`Self::forward_batch`] (one input
    /// clone — the f32 A/B engine does not compete on throughput).
    fn forward_batch_ws(&self, x: &Value, ws: &mut Workspace) -> Result<Tensor> {
        let _ = ws;
        self.forward_batch(x.clone())
    }

    /// The shape of one example (no batch dimension).
    fn example_shape(&self) -> Vec<usize> {
        match self.input() {
            InputKind::Image { channels, hw } => vec![channels, hw, hw],
            InputKind::Tokens { seq } => vec![seq],
        }
    }

    /// Validate a single example at submission time: dtype, shape, and
    /// (for token models) id range.  Rejecting here keeps a malformed
    /// request from poisoning the healthy requests batched with it.
    fn validate_example(&self, v: &Value) -> Result<()> {
        let want = self.example_shape();
        match (self.input(), v) {
            (InputKind::Image { .. }, Value::F32(t)) => {
                if t.shape != want {
                    let m = self.model();
                    bail!("{m}: want an f32 example of shape {want:?}, got {:?}", t.shape);
                }
            }
            (InputKind::Tokens { .. }, Value::I32(t)) => {
                if t.shape != want {
                    let m = self.model();
                    bail!("{m}: want i32 token ids of shape {want:?}, got {:?}", t.shape);
                }
                if let Some(vocab) = self.vocab() {
                    if let Some(&id) = t.data.iter().find(|&&id| id < 0 || id as usize >= vocab) {
                        bail!("{}: token id {id} out of range [0, {vocab})", self.model());
                    }
                }
            }
            (InputKind::Image { .. }, Value::I32(_)) => {
                bail!("{}: this model serves f32 image examples, got i32 data", self.model())
            }
            (InputKind::Tokens { .. }, Value::F32(_)) => {
                bail!("{}: this model serves i32 token examples, got f32 data", self.model())
            }
        }
        Ok(())
    }
}

impl Engine for QuantizedGraph {
    fn model(&self) -> &str {
        &self.model
    }

    fn input(&self) -> InputKind {
        self.input
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn vocab(&self) -> Option<usize> {
        // the inherent accessor, named explicitly so this cannot recurse
        QuantizedGraph::vocab(self)
    }

    fn forward_batch(&self, x: Value) -> Result<Tensor> {
        self.forward_owned(x)
    }

    fn forward_batch_ws(&self, x: &Value, ws: &mut Workspace) -> Result<Tensor> {
        // the planned executor: every activation/code/accumulator buffer
        // comes from the worker's workspace — zero steady-state allocs
        let b = x.shape().first().copied().unwrap_or(0);
        let data = self.forward_into(x, ws)?;
        Ok(match self.input {
            InputKind::Image { .. } => ws.tensor(&[b, self.classes], data),
            InputKind::Tokens { seq } => ws.tensor(&[b, seq, self.classes], data),
        })
    }
}

/// The fake-quant f32 serving baseline: executes the float
/// [`LayerGraph`] forward (`GraphStep::forward_logits`) at whatever
/// batch size the batcher produced.  Every call re-synthesizes a
/// manifest for the batch size and re-binds parameters — an intentional
/// non-optimization, since this engine exists to A/B the int8 path, not
/// to win benchmarks.
pub struct FloatEngine {
    graph: LayerGraph,
    id: StepId,
    params: ParamStore,
    qparams: Option<QParamStore>,
}

impl FloatEngine {
    /// Wrap a trained graph for f32 serving.  `qparams: None` serves the
    /// plain FP forward; `Some` fake-quants weights and activations per
    /// call like the `wXaY` fwd artifacts.
    pub fn new(
        graph: LayerGraph,
        params: ParamStore,
        qparams: Option<QParamStore>,
        w_bits: u32,
        a_bits: u32,
    ) -> FloatEngine {
        let (w_bits, a_bits) = if qparams.is_some() { (w_bits, a_bits) } else { (0, 0) };
        FloatEngine { graph, id: StepId { kind: StepKind::Fwd, w_bits, a_bits }, params, qparams }
    }
}

impl Engine for FloatEngine {
    fn model(&self) -> &str {
        &self.graph.model
    }

    fn input(&self) -> InputKind {
        self.graph.input
    }

    fn classes(&self) -> usize {
        self.graph.classes
    }

    fn vocab(&self) -> Option<usize> {
        fn find(layers: &[Layer]) -> Option<usize> {
            layers.iter().find_map(|l| match l {
                Layer::Embed(e) => Some(e.vocab),
                Layer::Residual(inner) => find(inner),
                _ => None,
            })
        }
        find(&self.graph.layers)
    }

    fn forward_batch(&self, x: Value) -> Result<Tensor> {
        let b = *x.shape().first().ok_or_else(|| anyhow!("empty batch"))?;
        let mut g = self.graph.clone();
        g.batch = b;
        let step = GraphStep::new(g, &format!("{}_serve_f32_b{b}", self.graph.model), self.id)?;
        let mut batch = Batch { f32s: BTreeMap::new(), i32s: BTreeMap::new(), count: b };
        // move the stacked batch in (no copy); zero labels satisfy the fwd
        // manifest's `y` input without touching the logits
        match (self.graph.input, x) {
            (InputKind::Image { .. }, Value::F32(t)) => {
                batch.i32s.insert("y".into(), ITensor::zeros(&[b]));
                batch.f32s.insert("x".into(), t);
            }
            (InputKind::Tokens { seq }, Value::I32(t)) => {
                batch.i32s.insert("y".into(), ITensor::zeros(&[b, seq]));
                batch.i32s.insert("x".into(), t);
            }
            _ => bail!("{}: batch dtype does not match the graph's input kind", self.graph.model),
        }
        let states = StateStore::init(&step.man);
        let ctx = BindCtx {
            params: &self.params,
            qparams: self.qparams.as_ref(),
            states: &states,
            batch: &batch,
            selection: None,
        };
        let inputs = bind_inputs(&step.man, &ctx)?;
        step.forward_logits(&inputs)
    }
}

/// Stack per-example inputs into one batched value (`[B, ...]`).  All
/// examples were validated at submission, so shapes agree; this only
/// concatenates.  Allocating form of [`stack_examples_ws`].
pub fn stack_examples(kind: InputKind, examples: &[Value]) -> Result<Value> {
    let mut ws = Workspace::new();
    stack_examples_ws(kind, examples, &mut ws)
}

/// Stack per-example inputs into one batched value over a caller-owned
/// workspace — the worker hot path; give the value back to `ws` after
/// the forward consumes it.
pub fn stack_examples_ws(
    kind: InputKind,
    examples: &[Value],
    ws: &mut Workspace,
) -> Result<Value> {
    let b = examples.len();
    match kind {
        InputKind::Image { channels, hw } => {
            let per = channels * hw * hw;
            let mut data = ws.take_f32(b * per);
            for (i, v) in examples.iter().enumerate() {
                data[i * per..(i + 1) * per].copy_from_slice(&v.f32()?.data);
            }
            Ok(Value::F32(ws.tensor(&[b, channels, hw, hw], data)))
        }
        InputKind::Tokens { seq } => {
            let mut data = ws.take_i32(b * seq);
            for (i, v) in examples.iter().enumerate() {
                data[i * seq..(i + 1) * seq].copy_from_slice(&v.i32()?.data);
            }
            Ok(Value::I32(ws.itensor(&[b, seq], data)))
        }
    }
}

/// Split batched logits `[B, ...]` into `B` per-example tensors of
/// shape `[...]` (the batch dimension dropped).  The per-example
/// tensors are freshly allocated — they are the response envelopes that
/// leave through the oneshots; the batched input buffer stays with the
/// caller for recycling.
pub fn split_logits(out: &Tensor, b: usize) -> Result<Vec<Tensor>> {
    if out.shape.first() != Some(&b) || b == 0 {
        bail!("cannot split logits {:?} into {b} examples", out.shape);
    }
    let shape: Vec<usize> = out.shape[1..].to_vec();
    let per: usize = shape.iter().product();
    if per == 0 {
        bail!("cannot split logits {:?}: zero-sized example dimension", out.shape);
    }
    Ok(out
        .data
        .chunks(per)
        .map(|c| Tensor { shape: shape.clone(), data: c.to_vec() })
        .collect())
}

/// Worker loop: consume batches until the batch queue is closed and
/// drained.  An engine failure on a batch resolves *every* request in it
/// with the error — no request is left hanging.
///
/// The engine is re-read from `slot` **per batch** (a handful of `Arc`
/// clones under a short lock): this is the hot-swap seam.  A
/// [`Registry::install`](super::registry::Registry::install) over the
/// same model replaces the slot between batches; a batch already popped
/// keeps the old engine `Arc` until its replies are sent, so the
/// outgoing graph is dropped exactly when its last in-flight batch
/// completes.  Every [`Reply`] names the engine (fingerprint +
/// generation) that actually computed it.
///
/// Each worker owns one [`Workspace`] reused across micro-batches: the
/// stacked input, every engine-internal buffer, and the batched logits
/// all recycle, so after the first batch at a given high-water size the
/// steady state performs zero heap allocations beyond the per-request
/// response envelopes.  A shrinking dynamic batch reuses the high-water
/// buffers; growing past them resizes once and plateaus.
pub fn run(slot: &Mutex<EngineSlot>, batches: &Arc<BoundedQueue<Vec<Request>>>, trace: &LaneTrace) {
    let mut ws = Workspace::new();
    while let Some(batch) = batches.pop() {
        process_batch(slot, batch, &mut ws, trace);
    }
}

/// Execute one micro-batch end to end: snapshot the engine slot, stack,
/// forward, split, resolve every request's oneshot, then publish the
/// batch's spans to the lane trace.  Factored out of [`run`] so the
/// zero-allocation test (`rust/tests/workspace_alloc.rs`) can drive the
/// exact serve hot path single-threaded under a counting allocator.
pub fn process_batch(
    slot: &Mutex<EngineSlot>,
    batch: Vec<Request>,
    ws: &mut Workspace,
    trace: &LaneTrace,
) {
    let b = batch.len();
    let snap = slot.lock().unwrap_or_else(|p| p.into_inner()).clone();
    let engine = &snap.engine;
    let mut inputs: Vec<Value> = Vec::with_capacity(b);
    let mut txs: Vec<OneshotSender<Result<Reply>>> = Vec::with_capacity(b);
    let mut spans: Vec<Span> = Vec::with_capacity(b);
    for r in batch {
        inputs.push(r.input);
        txs.push(r.tx);
        spans.push(r.span);
    }
    let result = match stack_examples_ws(engine.input(), &inputs, ws) {
        Ok(x) => {
            let y = engine.forward_batch_ws(&x, ws);
            ws.give_value(x);
            match y {
                Ok(y) => {
                    let parts = split_logits(&y, b);
                    ws.give_tensor(y);
                    parts
                }
                Err(e) => Err(e),
            }
        }
        Err(e) => Err(e),
    };
    let executed = Instant::now();
    let ok = result.is_ok();
    match result {
        Ok(parts) => {
            for (tx, logits) in txs.into_iter().zip(parts) {
                tx.send(Ok(Reply {
                    logits,
                    model: snap.model.clone(),
                    fingerprint: snap.fingerprint.clone(),
                    generation: snap.generation,
                }));
            }
        }
        Err(e) => {
            for tx in txs {
                tx.send(Err(anyhow!("{} serve: batch of {b} failed: {e}", snap.model)));
            }
        }
    }
    trace.publish_batch(&spans, executed, Instant::now(), ok);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_and_split_round_trip_images() {
        let kind = InputKind::Image { channels: 1, hw: 2 };
        let ex: Vec<Value> = (0..3)
            .map(|i| Value::F32(Tensor { shape: vec![1, 2, 2], data: vec![i as f32; 4] }))
            .collect();
        let x = stack_examples(kind, &ex).unwrap();
        assert_eq!(x.shape(), &[3, 1, 2, 2]);
        let out = Tensor { shape: vec![3, 5], data: (0..15).map(|v| v as f32).collect() };
        let parts = split_logits(&out, 3).unwrap();
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[1].shape, vec![5]);
        assert_eq!(parts[1].data, vec![5.0, 6.0, 7.0, 8.0, 9.0]);
    }

    #[test]
    fn stack_tokens_keeps_sequence_layout() {
        let kind = InputKind::Tokens { seq: 2 };
        let ex = [
            Value::I32(ITensor { shape: vec![2], data: vec![1, 2] }),
            Value::I32(ITensor { shape: vec![2], data: vec![3, 4] }),
        ];
        let x = stack_examples(kind, &ex).unwrap();
        assert_eq!(x.i32().unwrap().data, vec![1, 2, 3, 4]);
    }

    #[test]
    fn split_rejects_mismatched_batch() {
        let out = Tensor { shape: vec![3, 5], data: vec![0.0; 15] };
        assert!(split_logits(&out, 4).is_err());
    }
}
