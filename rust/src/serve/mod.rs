//! Batched int8 serving runtime (`efqat serve`): the layer between the
//! lowering boundary ([`crate::lower`]) and concurrent callers.
//!
//! Topology (all `std::thread` + `Condvar`, zero dependencies) — one
//! *lane* per registered model:
//!
//! ```text
//!             ┌ lane "m1": BoundedQueue<Request> ─► batcher ─► BoundedQueue<Vec<_>> ─► workers ┐
//!  submitters ┤                                                                               ├─► oneshot
//!             └ lane "m2": … (own queue/batcher/workers; swappable Mutex<EngineSlot>) ────────┘
//! ```
//!
//! * [`queue`] — the bounded MPSC queue + oneshot primitives; close is
//!   *draining*, so shutdown answers everything already accepted, and
//!   [`queue::BoundedQueue::try_push`] is the non-blocking admission
//!   edge.
//! * [`batcher`] — dynamic micro-batching: a batch flushes when it holds
//!   `max_batch` requests or `max_wait` after its first request,
//!   whichever comes first; FIFO in, FIFO out.
//! * [`worker`] — the pool: one engine forward per batch (amortizing the
//!   `u8×i8→i32` GEMMs), per-example logits routed back through each
//!   request's oneshot.  Per-example logits are bit-identical to a
//!   batch-of-1 forward (see `worker`'s module docs).  The engine is
//!   re-read from the model's [`registry::EngineSlot`] per batch — the
//!   hot-swap seam.
//! * [`registry`] — the multi-model registry: engines keyed by
//!   `(model, checkpoint fingerprint)`, zero-downtime checkpoint hot
//!   swap, per-model admission control (RFC 0005).
//! * [`protocol`] — the versioned JSONL request/response grammar (RFC
//!   `docs/rfcs/0002-serve-protocol.md`, v2: model routing) and the
//!   stdin/TCP drivers.
//!
//! The engines behind the lanes are [`worker::Engine`]s: the lowered
//! [`crate::lower::QuantizedGraph`] (`--exec int8`, the deployed
//! arithmetic) or the fake-quant [`worker::FloatEngine`] (`--exec f32`,
//! the A/B reference).

#![warn(missing_docs)]

pub mod batcher;
pub mod protocol;
pub mod queue;
pub mod registry;
pub mod replay;
pub mod trace;
pub mod worker;

use std::sync::Arc;
use std::time::Duration;

use crate::backend::Value;
use crate::cfg::Config;
use crate::error::{anyhow, bail, Result};
use crate::tensor::Tensor;

pub use batcher::{AdaptiveWindow, BatchCfg, BatchItem};
pub use registry::{EngineSlot, ModelStats, Registry, Reply, SubmitError};
pub use replay::{ReplayRecord, ReplayReport, TrafficRecorder};
pub use trace::{JsonlTraceRecorder, LaneTrace, Span, StagePcts, TraceStats, TraceSubscriber};
pub use worker::{Engine, FloatEngine, Request};

use queue::OneshotReceiver;

/// Serving-runtime knobs; construct via the validating
/// [`ServeCfg::builder`] (or [`ServeCfg::from_config`] for CLI/config
/// keys).  Direct struct construction stays possible for tests/benches
/// but skips validation.
#[derive(Clone, Copy, Debug)]
pub struct ServeCfg {
    /// Micro-batching policy (`--batch.max`, `--batch.wait-ms`).
    pub batch: BatchCfg,
    /// Worker threads running batches, per model lane (`--serve.workers`).
    pub workers: usize,
    /// Per-model request-queue capacity; a full queue rejects with
    /// `overloaded` (`--serve.queue-cap`).
    pub queue_cap: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg { batch: BatchCfg::default(), workers: 2, queue_cap: 1024 }
    }
}

impl ServeCfg {
    /// A builder seeded with the defaults; `build()` validates.
    pub fn builder() -> ServeCfgBuilder {
        let d = ServeCfg::default();
        ServeCfgBuilder {
            max_batch: d.batch.max_batch,
            wait_ms: d.batch.max_wait.as_secs_f32() * 1e3,
            adaptive: d.batch.adaptive,
            workers: d.workers,
            queue_cap: d.queue_cap,
        }
    }

    /// Read the serving knobs from config/CLI overrides — `batch.max`,
    /// `batch.wait-ms`, `batch.adaptive`, `serve.workers`,
    /// `serve.queue-cap` — and validate them: out-of-domain values (zero
    /// limits, negative or non-finite waits) are configuration errors,
    /// not silent fallbacks.
    pub fn from_config(cfg: &Config) -> Result<ServeCfg> {
        let b = ServeCfg::builder();
        b.max_batch(cfg.usize("batch.max", BatchCfg::default().max_batch))
            .max_wait_ms(cfg.f32("batch.wait-ms", BatchCfg::default().max_wait.as_secs_f32() * 1e3))
            .adaptive(cfg.bool("batch.adaptive", BatchCfg::default().adaptive))
            .workers(cfg.usize("serve.workers", ServeCfg::default().workers))
            .queue_cap(cfg.usize("serve.queue-cap", ServeCfg::default().queue_cap))
            .build()
    }
}

/// Validating builder for [`ServeCfg`]: rejects zero/contradictory
/// limits at construction instead of letting them surface as a wedged
/// runtime (a 0-worker pool never answers; a 0-capacity queue never
/// accepts).  `wait_ms == 0` stays expressible: "flush immediately".
#[derive(Clone, Copy, Debug)]
pub struct ServeCfgBuilder {
    max_batch: usize,
    wait_ms: f32,
    adaptive: bool,
    workers: usize,
    queue_cap: usize,
}

impl ServeCfgBuilder {
    /// Maximum requests per micro-batch (must be ≥ 1).
    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    /// Maximum wait after a batch's first request, in milliseconds
    /// (must be finite and ≥ 0; 0 = flush immediately).
    pub fn max_wait_ms(mut self, ms: f32) -> Self {
        self.wait_ms = ms;
        self
    }

    /// Adaptive flush window (`--batch.adaptive`): tune the partial-batch
    /// wait from the observed arrival rate, never exceeding the static
    /// `max_wait` bound.
    pub fn adaptive(mut self, on: bool) -> Self {
        self.adaptive = on;
        self
    }

    /// Worker threads per model lane (must be ≥ 1).
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    /// Per-model request-queue capacity (must be ≥ 1).  May be smaller
    /// than `max_batch`: the batcher then flushes on its deadline with
    /// whatever fits.
    pub fn queue_cap(mut self, n: usize) -> Self {
        self.queue_cap = n;
        self
    }

    /// Validate and produce the config.
    pub fn build(self) -> Result<ServeCfg> {
        if self.max_batch == 0 {
            bail!("serve config: batch.max must be >= 1 (a 0-batch never flushes)");
        }
        if self.workers == 0 {
            bail!("serve config: serve.workers must be >= 1 (a 0-worker pool never answers)");
        }
        if self.queue_cap == 0 {
            bail!("serve config: serve.queue-cap must be >= 1 (a 0-capacity queue never accepts)");
        }
        if !self.wait_ms.is_finite() || self.wait_ms < 0.0 {
            bail!("serve config: batch.wait-ms must be finite and >= 0, got {}", self.wait_ms);
        }
        Ok(ServeCfg {
            batch: BatchCfg {
                max_batch: self.max_batch,
                max_wait: Duration::from_secs_f32(self.wait_ms / 1e3),
                adaptive: self.adaptive,
            },
            workers: self.workers,
            queue_cap: self.queue_cap,
        })
    }
}

/// Handle for one submitted request; resolves to its logits (or the
/// full [`Reply`] with serving identity via [`Ticket::wait_reply`]).
pub struct Ticket {
    pub(crate) rx: OneshotReceiver<Result<Reply>>,
}

impl Ticket {
    /// Block until this request's batch executed.  An abandoned request
    /// (worker died mid-batch) is an error, never a hang.
    pub fn wait(self) -> Result<Tensor> {
        self.wait_reply().map(|r| r.logits)
    }

    /// Like [`Ticket::wait`], but keeps the reply envelope: which
    /// model/fingerprint/generation computed the logits.
    pub fn wait_reply(self) -> Result<Reply> {
        self.rx
            .recv()
            .unwrap_or_else(|| Err(anyhow!("request abandoned: serving runtime shut down")))
    }
}

/// A running serving runtime over a [`Registry`]: per-model lanes
/// (queue + batcher + worker pool) with hot-swappable engines.
///
/// Dropping (or [`shutdown`](Server::shutdown)ing) the server closes
/// every lane's intake, drains every queued request through the
/// workers, and joins all threads — accepted requests are always
/// answered.
pub struct Server {
    registry: Registry,
}

impl Server {
    /// Start lanes for every model in `registry` with `cfg`.  Models
    /// installed into the registry later get a lane automatically.
    /// Fails if the registry's lanes were already started.
    pub fn start(registry: Registry, cfg: ServeCfg) -> Result<Server> {
        registry.start(cfg)?;
        Ok(Server { registry })
    }

    /// Single-engine compat shim: a fresh one-model registry (the
    /// engine's own model name, fingerprint `"unversioned"`, default
    /// model) — the pre-registry `Server::start(engine, cfg)` shape.
    pub fn single(engine: Arc<dyn Engine>, cfg: ServeCfg) -> Server {
        let registry = Registry::new();
        let name = engine.model().to_string();
        registry.install(&name, engine, "unversioned").expect("install single engine");
        Server::start(registry, cfg).expect("start fresh registry")
    }

    /// The registry behind this server (install/swap/retire live there).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Submit one example to the default model.  Validates dtype/shape/
    /// token range immediately (a malformed example never joins a
    /// batch); a full lane or shut-down runtime is an error.
    pub fn submit(&self, input: Value) -> Result<Ticket> {
        self.registry.submit(None, input).map_err(Into::into)
    }

    /// Submit one example to `model` (or the default model for `None`),
    /// keeping the typed admission verdict — protocol drivers match on
    /// [`SubmitError::code`].
    pub fn try_submit(&self, model: Option<&str>, input: Value) -> registry::SubmitResult {
        self.registry.submit(model, input)
    }

    /// Requests currently queued (not yet batched) across all models.
    pub fn pending(&self) -> usize {
        self.registry.pending()
    }

    /// Per-model live counters (queue depth, active fingerprint, ...).
    pub fn stats(&self) -> Vec<ModelStats> {
        self.registry.stats()
    }

    /// Close every intake, drain every queued request, join all threads.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // close-then-join IS the drain: each batcher pops until its
        // request queue is empty, closes its batch queue, and the
        // workers pop until that is empty too
        self.registry.shutdown();
    }
}

#[cfg(test)]
pub(crate) mod test_fixture {
    use crate::lower::{lower, QuantizedGraph};

    /// A lowered graph over the shared synthetic fixture
    /// ([`crate::testing::synth_lowering_fixture`]) — what the serve unit
    /// tests pool workers around.
    pub fn lowered(model: &str) -> QuantizedGraph {
        let (g, params, q) = crate::testing::synth_lowering_fixture(model);
        lower(&g, &params, &q, 8, 8).unwrap()
    }

    pub fn lowered_mlp() -> QuantizedGraph {
        lowered("mlp")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::time::Duration;

    fn image(seed: u64) -> Value {
        let mut rng = crate::rng::Pcg64::new(seed);
        Value::F32(Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) })
    }

    #[test]
    fn single_request_matches_direct_forward() {
        let qg = std::sync::Arc::new(test_fixture::lowered_mlp());
        let server = Server::single(qg.clone(), ServeCfg::default());
        let x = image(3);
        let got = server.submit(x.clone()).unwrap().wait().unwrap();
        let stacked = crate::serve::worker::stack_examples(qg.input, &[x]).unwrap();
        let want = qg.forward(&stacked).unwrap();
        assert_eq!(got.shape, vec![10]);
        assert_eq!(got.data, want.data, "served logits must be bit-identical");
        server.shutdown();
    }

    #[test]
    fn single_shim_reply_carries_unversioned_identity() {
        let server =
            Server::single(std::sync::Arc::new(test_fixture::lowered_mlp()), ServeCfg::default());
        assert_eq!(server.registry().default_model().as_deref(), Some("mlp"));
        let reply = server.submit(image(9)).unwrap().wait_reply().unwrap();
        assert_eq!(&*reply.model, "mlp");
        assert_eq!(&*reply.fingerprint, "unversioned");
        assert_eq!(reply.generation, 1);
    }

    #[test]
    fn submit_rejects_malformed_examples() {
        let engine = std::sync::Arc::new(test_fixture::lowered_mlp());
        let server = Server::single(engine, ServeCfg::default());
        let bad = Value::F32(Tensor::zeros(&[3, 4, 4]));
        let err = server.submit(bad).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");
        let bad = Value::I32(crate::tensor::ITensor::zeros(&[16]));
        let err = server.submit(bad).unwrap_err().to_string();
        assert!(err.contains("f32"), "{err}");
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        // one slow-ish config: big max_batch + long deadline would hold
        // requests hostage if shutdown did not drain
        let cfg = ServeCfg {
            batch: BatchCfg { max_batch: 64, max_wait: Duration::from_secs(30), adaptive: false },
            workers: 1,
            queue_cap: 64,
        };
        let server = Server::single(std::sync::Arc::new(test_fixture::lowered_mlp()), cfg);
        let tickets: Vec<Ticket> = (0..5).map(|i| server.submit(image(i)).unwrap()).collect();
        server.shutdown(); // closes intake, drains, joins
        for t in tickets {
            assert_eq!(t.wait().unwrap().shape, vec![10]);
        }
    }

    #[test]
    fn serve_cfg_reads_cli_keys() {
        let mut cfg = crate::cfg::Config::empty();
        cfg.set("batch.max", "8");
        cfg.set("batch.wait-ms", "0.5");
        cfg.set("serve.workers", "3");
        cfg.set("serve.queue-cap", "16");
        cfg.set("batch.adaptive", "true");
        let sc = ServeCfg::from_config(&cfg).unwrap();
        assert_eq!(sc.batch.max_batch, 8);
        assert!(sc.batch.adaptive);
        assert!(!ServeCfg::from_config(&crate::cfg::Config::empty()).unwrap().batch.adaptive);
        // f32 ms → Duration conversion: exact to within a nanosecond
        let wait = sc.batch.max_wait.as_nanos() as i128;
        assert!((wait - 500_000).abs() <= 1, "{wait}ns");
        assert_eq!(sc.workers, 3);
        assert_eq!(sc.queue_cap, 16);
    }

    #[test]
    fn builder_rejects_zero_and_out_of_domain_limits() {
        assert!(ServeCfg::builder().max_batch(0).build().is_err());
        assert!(ServeCfg::builder().workers(0).build().is_err());
        assert!(ServeCfg::builder().queue_cap(0).build().is_err());
        for bad in [-1.0, f32::NAN, f32::INFINITY] {
            let err = ServeCfg::builder().max_wait_ms(bad).build();
            assert!(err.is_err(), "wait-ms {bad} must be rejected");
        }
        // zero wait stays expressible: "flush immediately"
        let sc = ServeCfg::builder().max_wait_ms(0.0).build().unwrap();
        assert_eq!(sc.batch.max_wait, Duration::ZERO);
        // queue_cap < max_batch is fine: the batcher flushes what fits
        assert!(ServeCfg::builder().max_batch(64).queue_cap(8).build().is_ok());
    }

    #[test]
    fn out_of_domain_config_values_are_errors_not_fallbacks() {
        for bad in ["-1", "nan", "inf"] {
            let mut cfg = crate::cfg::Config::empty();
            cfg.set("batch.wait-ms", bad);
            let err = ServeCfg::from_config(&cfg);
            assert!(err.is_err(), "wait-ms {bad} must be a config error");
        }
        let mut cfg = crate::cfg::Config::empty();
        cfg.set("serve.workers", "0");
        assert!(ServeCfg::from_config(&cfg).is_err());
        // zero wait stays expressible: "flush immediately"
        let mut cfg = crate::cfg::Config::empty();
        cfg.set("batch.wait-ms", "0");
        assert_eq!(ServeCfg::from_config(&cfg).unwrap().batch.max_wait, Duration::ZERO);
    }
}
