//! Batched int8 serving runtime (`efqat serve`): the layer between the
//! lowering boundary ([`crate::lower`]) and concurrent callers.
//!
//! Topology (all `std::thread` + `Condvar`, zero dependencies):
//!
//! ```text
//!  submitters ──► BoundedQueue<Request> ──► batcher ──► BoundedQueue<Vec<Request>> ──► workers
//!  (bounded: backpressure)      (flush on max_batch │ max_wait)            (shared Arc<Engine>)
//!        ▲                                                                     │
//!        └────────────────── oneshot per request (logits or error) ◄───────────┘
//! ```
//!
//! * [`queue`] — the bounded MPSC queue + oneshot primitives; close is
//!   *draining*, so shutdown answers everything already accepted.
//! * [`batcher`] — dynamic micro-batching: a batch flushes when it holds
//!   `max_batch` requests or `max_wait` after its first request,
//!   whichever comes first; FIFO in, FIFO out.
//! * [`worker`] — the pool: one engine forward per batch (amortizing the
//!   `u8×i8→i32` GEMMs), per-example logits routed back through each
//!   request's oneshot.  Per-example logits are bit-identical to a
//!   batch-of-1 forward (see `worker`'s module docs).
//! * [`protocol`] — the versioned JSONL request/response grammar (RFC
//!   `docs/rfcs/0002-serve-protocol.md`) and the stdin/TCP drivers.
//!
//! The engine behind the pool is an [`worker::Engine`]: the lowered
//! [`crate::lower::QuantizedGraph`] (`--exec int8`, the deployed
//! arithmetic) or the fake-quant [`worker::FloatEngine`] (`--exec f32`,
//! the A/B reference).

#![warn(missing_docs)]

pub mod batcher;
pub mod protocol;
pub mod queue;
pub mod worker;

use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::backend::Value;
use crate::cfg::Config;
use crate::error::{anyhow, Result};
use crate::tensor::Tensor;

pub use batcher::BatchCfg;
pub use worker::{Engine, FloatEngine, Request};

use queue::{oneshot, BoundedQueue, OneshotReceiver};

/// Serving-runtime knobs; every field maps to a CLI/config key
/// (see [`ServeCfg::from_config`]).
#[derive(Clone, Copy, Debug)]
pub struct ServeCfg {
    /// Micro-batching policy (`--batch.max`, `--batch.wait-ms`).
    pub batch: BatchCfg,
    /// Worker threads running batches (`--serve.workers`).
    pub workers: usize,
    /// Request-queue capacity; a full queue blocks submitters
    /// (`--serve.queue-cap`).
    pub queue_cap: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg { batch: BatchCfg::default(), workers: 2, queue_cap: 1024 }
    }
}

impl ServeCfg {
    /// Read the serving knobs from config/CLI overrides:
    /// `batch.max`, `batch.wait-ms`, `serve.workers`, `serve.queue-cap`.
    pub fn from_config(cfg: &Config) -> ServeCfg {
        let d = ServeCfg::default();
        // sanitize before Duration::from_secs_f32, which panics on
        // negative/NaN/inf input: out-of-domain waits fall back to the
        // default (0 = "flush immediately" stays expressible)
        let default_ms = d.batch.max_wait.as_secs_f32() * 1e3;
        let mut wait_ms = cfg.f32("batch.wait-ms", default_ms);
        if !wait_ms.is_finite() || wait_ms < 0.0 {
            wait_ms = default_ms;
        }
        ServeCfg {
            batch: BatchCfg {
                max_batch: cfg.usize("batch.max", d.batch.max_batch),
                max_wait: Duration::from_secs_f32(wait_ms / 1e3),
            },
            workers: cfg.usize("serve.workers", d.workers).max(1),
            queue_cap: cfg.usize("serve.queue-cap", d.queue_cap),
        }
    }
}

/// Handle for one submitted request; resolves to its logits.
pub struct Ticket {
    rx: OneshotReceiver<Result<Tensor>>,
}

impl Ticket {
    /// Block until this request's batch executed.  An abandoned request
    /// (worker died mid-batch) is an error, never a hang.
    pub fn wait(self) -> Result<Tensor> {
        self.rx
            .recv()
            .unwrap_or_else(|| Err(anyhow!("request abandoned: serving runtime shut down")))
    }
}

/// A running serving runtime: queue + batcher thread + worker pool
/// around a shared engine.
///
/// Dropping (or [`shutdown`](Server::shutdown)ing) the server closes the
/// intake, drains every queued request through the workers, and joins
/// all threads — accepted requests are always answered.
pub struct Server {
    engine: Arc<dyn Engine>,
    requests: Arc<BoundedQueue<Request>>,
    threads: Vec<JoinHandle<()>>,
}

impl Server {
    /// Spawn the batcher and worker threads around `engine`.
    pub fn start(engine: Arc<dyn Engine>, cfg: ServeCfg) -> Server {
        let requests: Arc<BoundedQueue<Request>> = BoundedQueue::new(cfg.queue_cap);
        // small batch buffer: enough to keep every worker busy without
        // letting latency hide in a deep intermediate queue
        let batches: Arc<BoundedQueue<Vec<Request>>> = BoundedQueue::new(cfg.workers.max(1) * 2);
        let mut threads = Vec::with_capacity(cfg.workers + 1);
        {
            let (rq, bq) = (requests.clone(), batches.clone());
            threads.push(
                std::thread::Builder::new()
                    .name("efqat-batcher".into())
                    .spawn(move || batcher::run(&rq, &bq, cfg.batch))
                    .expect("spawn batcher"),
            );
        }
        for i in 0..cfg.workers.max(1) {
            let (eng, bq) = (engine.clone(), batches.clone());
            threads.push(
                std::thread::Builder::new()
                    .name(format!("efqat-worker-{i}"))
                    .spawn(move || worker::run(&eng, &bq))
                    .expect("spawn worker"),
            );
        }
        Server { engine, requests, threads }
    }

    /// The engine this server answers with.
    pub fn engine(&self) -> &Arc<dyn Engine> {
        &self.engine
    }

    /// Submit one example for inference.  Validates dtype/shape/token
    /// range immediately (a malformed example never joins a batch),
    /// then enqueues — blocking while the queue is full (backpressure).
    /// Fails once the server is shut down.
    pub fn submit(&self, input: Value) -> Result<Ticket> {
        self.engine.validate_example(&input)?;
        let (tx, rx) = oneshot();
        self.requests
            .push(Request { input, tx })
            .map_err(|_| anyhow!("{} serve: server is shut down", self.engine.model()))?;
        Ok(Ticket { rx })
    }

    /// Requests currently queued (not yet batched) — telemetry/tests.
    pub fn pending(&self) -> usize {
        self.requests.len()
    }

    /// Close the intake, drain every queued request, join all threads.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // close-then-join IS the drain: the batcher pops until the
        // request queue is empty, closes the batch queue, and the
        // workers pop until that is empty too
        self.requests.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
pub(crate) mod test_fixture {
    use crate::lower::{lower, QuantizedGraph};

    /// A lowered graph over the shared synthetic fixture
    /// ([`crate::testing::synth_lowering_fixture`]) — what the serve unit
    /// tests pool workers around.
    pub fn lowered(model: &str) -> QuantizedGraph {
        let (g, params, q) = crate::testing::synth_lowering_fixture(model);
        lower(&g, &params, &q, 8, 8).unwrap()
    }

    pub fn lowered_mlp() -> QuantizedGraph {
        lowered("mlp")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::time::Duration;

    fn image(seed: u64) -> Value {
        let mut rng = crate::rng::Pcg64::new(seed);
        Value::F32(Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) })
    }

    #[test]
    fn single_request_matches_direct_forward() {
        let qg = std::sync::Arc::new(test_fixture::lowered_mlp());
        let server = Server::start(qg.clone(), ServeCfg::default());
        let x = image(3);
        let got = server.submit(x.clone()).unwrap().wait().unwrap();
        let stacked = crate::serve::worker::stack_examples(qg.input, &[x]).unwrap();
        let want = qg.forward(&stacked).unwrap();
        assert_eq!(got.shape, vec![10]);
        assert_eq!(got.data, want.data, "served logits must be bit-identical");
        server.shutdown();
    }

    #[test]
    fn submit_rejects_malformed_examples() {
        let engine = std::sync::Arc::new(test_fixture::lowered_mlp());
        let server = Server::start(engine, ServeCfg::default());
        let bad = Value::F32(Tensor::zeros(&[3, 4, 4]));
        let err = server.submit(bad).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");
        let bad = Value::I32(crate::tensor::ITensor::zeros(&[16]));
        let err = server.submit(bad).unwrap_err().to_string();
        assert!(err.contains("f32"), "{err}");
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        // one slow-ish config: big max_batch + long deadline would hold
        // requests hostage if shutdown did not drain
        let cfg = ServeCfg {
            batch: BatchCfg { max_batch: 64, max_wait: Duration::from_secs(30) },
            workers: 1,
            queue_cap: 64,
        };
        let server = Server::start(std::sync::Arc::new(test_fixture::lowered_mlp()), cfg);
        let tickets: Vec<Ticket> =
            (0..5).map(|i| server.submit(image(i)).unwrap()).collect();
        server.shutdown(); // closes intake, drains, joins
        for t in tickets {
            assert_eq!(t.wait().unwrap().shape, vec![10]);
        }
    }

    #[test]
    fn serve_cfg_reads_cli_keys() {
        let mut cfg = crate::cfg::Config::empty();
        cfg.set("batch.max", "8");
        cfg.set("batch.wait-ms", "0.5");
        cfg.set("serve.workers", "3");
        cfg.set("serve.queue-cap", "16");
        let sc = ServeCfg::from_config(&cfg);
        assert_eq!(sc.batch.max_batch, 8);
        // f32 ms → Duration conversion: exact to within a nanosecond
        let wait = sc.batch.max_wait.as_nanos() as i128;
        assert!((wait - 500_000).abs() <= 1, "{wait}ns");
        assert_eq!(sc.workers, 3);
        assert_eq!(sc.queue_cap, 16);
    }

    #[test]
    fn out_of_domain_wait_ms_falls_back_instead_of_panicking() {
        for bad in ["-1", "nan", "inf"] {
            let mut cfg = crate::cfg::Config::empty();
            cfg.set("batch.wait-ms", bad);
            let sc = ServeCfg::from_config(&cfg);
            assert_eq!(sc.batch.max_wait, BatchCfg::default().max_wait, "{bad}");
        }
        // zero stays expressible: "flush immediately"
        let mut cfg = crate::cfg::Config::empty();
        cfg.set("batch.wait-ms", "0");
        assert_eq!(ServeCfg::from_config(&cfg).batch.max_wait, Duration::ZERO);
    }
}
