//! Artifact manifests, parameter/state stores, and checkpoint I/O.
//!
//! The manifest is the ABI between the layers: ordered input/output
//! tensor specs plus the model's parameter inventory (shapes, initializer
//! recipes, kinds).  `python/compile/aot.py` emits it as JSON for the
//! PJRT artifacts; [`crate::graph::build_manifest`] synthesizes the same
//! structure for the native layer graphs.  The coordinator builds a
//! [`ParamStore`] from it (so rust owns initialization — python never
//! ships weights) and binds literals by manifest order at execution time.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::error::{anyhow, bail, Context, Result};
use crate::json::Json;
use crate::quant::{weight_scales, ActQParams};
use crate::rng::Pcg64;
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub role: String,
    pub of: Option<String>,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub enum Init {
    HeConv(usize),
    HeLin(usize),
    Normal(f32),
    Zeros,
    Ones,
}

#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
    /// 'weight' | 'bias' | 'norm' | 'embed'
    pub kind: String,
}

#[derive(Clone, Debug)]
pub struct StateInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String, // 'zeros' | 'ones'
}

#[derive(Clone, Debug)]
pub struct WSite {
    pub name: String,
    pub c_out: usize,
    pub size: usize,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub model: String,
    pub kind: String,     // 'train' | 'fwd' | 'calib'
    pub sel_mode: String, // 'fp' | 'ratio' | 'lwpn' | ''
    pub ratio: f32,
    pub w_bits: u32,
    pub a_bits: u32,
    pub batch_size: usize,
    pub params: Vec<ParamInfo>,
    pub states: Vec<StateInfo>,
    pub wsites: Vec<WSite>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.get("name")?.str()?.to_string(),
        shape: j.get("shape")?.shape()?,
        dtype: match j.get("dtype")?.str()? {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            other => bail!("unknown dtype {other}"),
        },
        role: j.get("role")?.str()?.to_string(),
        of: j.opt("of").map(|v| v.str().map(str::to_string)).transpose()?,
    })
}

impl Manifest {
    /// Position of a named output in the manifest's positional output
    /// order — hot loops resolve names once and index thereafter.
    pub fn out_pos(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o.name == name)
            .ok_or_else(|| anyhow!("{}: manifest has no output {name:?}", self.name))
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&src).with_context(|| format!("parsing manifest {}", path.display()))
    }

    pub fn parse(src: &str) -> Result<Manifest> {
        let j = Json::parse(src)?;
        let params = j
            .get("params")?
            .arr()?
            .iter()
            .map(|p| {
                let init = p.get("init")?.arr()?;
                let kind0 = init
                    .first()
                    .ok_or_else(|| anyhow!("empty init"))?
                    .str()?;
                let init = match kind0 {
                    "he_conv" => Init::HeConv(init[1].usize()?),
                    "he_lin" => Init::HeLin(init[1].usize()?),
                    "normal" => Init::Normal(init[1].num()? as f32),
                    "zeros" => Init::Zeros,
                    "ones" => Init::Ones,
                    other => bail!("unknown init {other}"),
                };
                Ok(ParamInfo {
                    name: p.get("name")?.str()?.to_string(),
                    shape: p.get("shape")?.shape()?,
                    init,
                    kind: p.get("kind")?.str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let states = j
            .get("states")?
            .arr()?
            .iter()
            .map(|s| {
                Ok(StateInfo {
                    name: s.get("name")?.str()?.to_string(),
                    shape: s.get("shape")?.shape()?,
                    init: s.get("init")?.str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let wsites = j
            .get("wsites")?
            .arr()?
            .iter()
            .map(|s| {
                Ok(WSite {
                    name: s.get("name")?.str()?.to_string(),
                    c_out: s.get("c_out")?.usize()?,
                    size: s.get("size")?.usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            name: j.get("name")?.str()?.to_string(),
            model: j.get("model")?.str()?.to_string(),
            kind: j.get("kind")?.str()?.to_string(),
            sel_mode: j.opt("sel_mode").map(|v| v.str().unwrap_or("")).unwrap_or("").to_string(),
            ratio: j.opt("ratio").and_then(|v| v.num().ok()).unwrap_or(1.0) as f32,
            w_bits: j.get("w_bits")?.usize()? as u32,
            a_bits: j.get("a_bits")?.usize()? as u32,
            batch_size: j.get("batch_size")?.usize()?,
            params,
            states,
            wsites,
            inputs: j.get("inputs")?.arr()?.iter().map(parse_io).collect::<Result<Vec<_>>>()?,
            outputs: j.get("outputs")?.arr()?.iter().map(parse_io).collect::<Result<Vec<_>>>()?,
        })
    }
}

/// All trainable tensors of a model, keyed by parameter name.
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    pub map: BTreeMap<String, Tensor>,
}

impl ParamStore {
    /// Initialize from the manifest's recipes (deterministic per seed —
    /// matches the distribution, not the values, of the python test init).
    pub fn init(manifest: &Manifest, seed: u64) -> ParamStore {
        let mut rng = Pcg64::new(seed);
        let mut map = BTreeMap::new();
        for p in &manifest.params {
            let n: usize = p.shape.iter().product();
            let data = match p.init {
                Init::HeConv(fan) | Init::HeLin(fan) => {
                    let std = (2.0 / fan as f32).sqrt();
                    rng.normal_vec(n, std)
                }
                Init::Normal(std) => rng.normal_vec(n, std),
                Init::Zeros => vec![0.0; n],
                Init::Ones => vec![1.0; n],
            };
            map.insert(p.name.clone(), Tensor { shape: p.shape.clone(), data });
        }
        ParamStore { map }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).ok_or_else(|| anyhow!("missing param {name:?}"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.map.get_mut(name).ok_or_else(|| anyhow!("missing param {name:?}"))
    }

    pub fn total_elems(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }
}

/// BN running statistics and any other threaded state.
#[derive(Clone, Debug, Default)]
pub struct StateStore {
    pub map: BTreeMap<String, Tensor>,
}

impl StateStore {
    pub fn init(manifest: &Manifest) -> StateStore {
        let map = manifest
            .states
            .iter()
            .map(|s| {
                let t = if s.init == "ones" {
                    Tensor::ones(&s.shape)
                } else {
                    Tensor::zeros(&s.shape)
                };
                (s.name.clone(), t)
            })
            .collect();
        StateStore { map }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).ok_or_else(|| anyhow!("missing state {name:?}"))
    }
}

/// Quantization parameters: per-site weight scales (vectors) and per-site
/// activation scale/zero-point scalars.
#[derive(Clone, Debug, Default)]
pub struct QParamStore {
    pub sw: BTreeMap<String, Tensor>,
    pub act: BTreeMap<String, ActQParams>,
}

impl QParamStore {
    /// PTQ weight-scale initialization (Eq. 4) from the current weights.
    pub fn init_weight_scales(&mut self, manifest: &Manifest, params: &ParamStore, bits: u32) {
        for site in &manifest.wsites {
            let w = params.get(&site.name).expect("wsite param");
            let scales = weight_scales(&w.row_abs_max(), bits);
            self.sw.insert(site.name.clone(), Tensor { shape: vec![site.c_out], data: scales });
        }
    }
}

// ---------------------------------------------------------------------------
// Checkpoint I/O: a simple length-prefixed binary format (name, shape, f32 LE)
// ---------------------------------------------------------------------------

const CKPT_MAGIC: &[u8; 8] = b"EFQATCK1";

pub fn save_checkpoint(path: &Path, sections: &[(&str, &BTreeMap<String, Tensor>)]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(CKPT_MAGIC)?;
    f.write_all(&(sections.len() as u32).to_le_bytes())?;
    for (section, map) in sections {
        write_str(&mut f, section)?;
        f.write_all(&(map.len() as u32).to_le_bytes())?;
        for (name, t) in map.iter() {
            write_str(&mut f, name)?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            for &x in &t.data {
                f.write_all(&x.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

pub fn load_checkpoint(path: &Path) -> Result<BTreeMap<String, BTreeMap<String, Tensor>>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != CKPT_MAGIC {
        bail!("{} is not an EfQAT checkpoint", path.display());
    }
    let n_sections = read_u32(&mut f)?;
    let mut out = BTreeMap::new();
    for _ in 0..n_sections {
        let section = read_str(&mut f)?;
        let n = read_u32(&mut f)?;
        let mut map = BTreeMap::new();
        for _ in 0..n {
            let name = read_str(&mut f)?;
            let ndim = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut b = [0u8; 8];
                f.read_exact(&mut b)?;
                shape.push(u64::from_le_bytes(b) as usize);
            }
            let count: usize = shape.iter().product();
            let mut data = vec![0f32; count];
            for x in data.iter_mut() {
                let mut b = [0u8; 4];
                f.read_exact(&mut b)?;
                *x = f32::from_le_bytes(b);
            }
            map.insert(name, Tensor { shape, data });
        }
        out.insert(section, map);
    }
    Ok(out)
}

fn write_str<W: Write>(w: &mut W, s: &str) -> Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_str<R: Read>(r: &mut R) -> Result<String> {
    let n = read_u32(r)? as usize;
    let mut b = vec![0u8; n];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "name": "toy_w8a8_train_r25", "model": "toy", "kind": "train",
      "sel_mode": "ratio", "ratio": 0.25, "w_bits": 8, "a_bits": 8,
      "batch_size": 4,
      "params": [
        {"name": "fc.w", "shape": [8, 4], "init": ["he_lin", 4], "kind": "weight"},
        {"name": "fc.b", "shape": [8], "init": ["zeros"], "kind": "bias"},
        {"name": "bn.g", "shape": [8], "init": ["ones"], "kind": "norm"}
      ],
      "states": [{"name": "bn.rm", "shape": [8], "init": "zeros"}],
      "wsites": [{"name": "fc.w", "c_out": 8, "size": 32}],
      "inputs": [
        {"name": "fc.w", "shape": [8, 4], "dtype": "f32", "role": "param"},
        {"name": "id:fc.w", "shape": [2], "dtype": "i32", "role": "index", "of": "fc.w"}
      ],
      "outputs": [
        {"name": "loss", "shape": [1], "dtype": "f32", "role": "loss"},
        {"name": "d:fc.w", "shape": [2, 4], "dtype": "f32", "role": "grad", "of": "fc.w"}
      ]
    }"#;

    #[test]
    fn manifest_round_trip() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.name, "toy_w8a8_train_r25");
        assert_eq!(m.ratio, 0.25);
        assert_eq!(m.params.len(), 3);
        assert_eq!(m.wsites[0].c_out, 8);
        assert_eq!(m.inputs[1].dtype, Dtype::I32);
        assert_eq!(m.outputs[1].of.as_deref(), Some("fc.w"));
    }

    #[test]
    fn param_store_init_follows_recipes() {
        let m = Manifest::parse(MANIFEST).unwrap();
        let p = ParamStore::init(&m, 1);
        assert_eq!(p.get("fc.w").unwrap().shape, vec![8, 4]);
        assert!(p.get("fc.b").unwrap().data.iter().all(|&x| x == 0.0));
        assert!(p.get("bn.g").unwrap().data.iter().all(|&x| x == 1.0));
        // he init spread: std = sqrt(2/4) ≈ 0.707; values should be varied
        let w = p.get("fc.w").unwrap();
        assert!(w.data.iter().any(|&x| x.abs() > 0.1));
        // same seed → same init, different seed → different
        let p2 = ParamStore::init(&m, 1);
        assert_eq!(p.get("fc.w").unwrap().data, p2.get("fc.w").unwrap().data);
        let p3 = ParamStore::init(&m, 2);
        assert_ne!(p.get("fc.w").unwrap().data, p3.get("fc.w").unwrap().data);
    }

    #[test]
    fn qparam_weight_scale_init() {
        let m = Manifest::parse(MANIFEST).unwrap();
        let p = ParamStore::init(&m, 1);
        let mut q = QParamStore::default();
        q.init_weight_scales(&m, &p, 8);
        let sw = &q.sw["fc.w"];
        assert_eq!(sw.shape, vec![8]);
        let w = p.get("fc.w").unwrap();
        for r in 0..8 {
            let maxabs = w.row(r).iter().fold(0f32, |a, &b| a.max(b.abs()));
            assert!((sw.data[r] - maxabs / 127.0).abs() < 1e-7);
        }
    }

    #[test]
    fn checkpoint_round_trip() {
        let dir = std::env::temp_dir().join("efqat_test_ckpt");
        let path = dir.join("a.ckpt");
        let mut params = BTreeMap::new();
        let w = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        params.insert("w".to_string(), w);
        let mut states = BTreeMap::new();
        states.insert("rm".to_string(), Tensor::zeros(&[3]));
        save_checkpoint(&path, &[("params", &params), ("states", &states)]).unwrap();
        let loaded = load_checkpoint(&path).unwrap();
        assert_eq!(loaded["params"]["w"], params["w"]);
        assert_eq!(loaded["states"]["rm"], states["rm"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_rejects_garbage() {
        let dir = std::env::temp_dir().join("efqat_test_ckpt2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load_checkpoint(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
