//! Record/replay determinism tests (RFC 0006): a trace captured from a
//! live multi-model registry, re-issued at 1× and at 8×, must produce
//! replies that are **bit-identical** to offline evaluation of the same
//! examples, in the FIFO order the records were issued — speedup is a
//! scheduling lever, never a correctness one.

use std::sync::Arc;
use std::time::Duration;

use efqat::backend::Value;
use efqat::lower::{lower, QuantizedGraph};
use efqat::serve::replay::{load_trace, replay, ReplayRecord, TrafficRecorder};
use efqat::serve::{BatchCfg, Registry, ServeCfg, Server};
use efqat::tensor::{ITensor, Tensor};

fn fixture(model: &str) -> QuantizedGraph {
    let (g, params, q) = efqat::testing::synth_lowering_fixture(model);
    lower(&g, &params, &q, 8, 8).unwrap()
}

fn serve_cfg(max_batch: usize, wait: Duration, workers: usize, adaptive: bool) -> ServeCfg {
    let batch = BatchCfg { max_batch, max_wait: wait, adaptive };
    ServeCfg { batch, workers, queue_cap: 256 }
}

/// Re-shape one example into a batch of 1 — the offline reference every
/// replayed reply must match bit for bit.
fn unit_batch(v: &Value) -> Value {
    match v {
        Value::F32(t) => {
            let mut shape = vec![1];
            shape.extend_from_slice(&t.shape);
            Value::F32(Tensor { shape, data: t.data.clone() })
        }
        Value::I32(t) => {
            let mut shape = vec![1];
            shape.extend_from_slice(&t.shape);
            Value::I32(ITensor { shape, data: t.data.clone() })
        }
    }
}

fn two_model_server(adaptive: bool) -> (Server, Arc<QuantizedGraph>, Arc<QuantizedGraph>) {
    let mlp = Arc::new(fixture("mlp"));
    let tf = Arc::new(fixture("tiny_tf"));
    let registry = Registry::new();
    registry.install("mlp", mlp.clone(), "fp-mlp").unwrap();
    registry.install("tf", tf.clone(), "fp-tf").unwrap();
    let server =
        Server::start(registry, serve_cfg(8, Duration::from_millis(1), 2, adaptive)).unwrap();
    (server, mlp, tf)
}

/// A deterministic interleaved two-model request stream: even indices
/// are mlp images, odd indices are tiny_tf token rows.
fn traffic(n: usize) -> Vec<(String, Value)> {
    let mut rng = efqat::rng::Pcg64::new(4242);
    (0..n)
        .map(|i| {
            if i % 2 == 0 {
                let x = Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) };
                ("mlp".to_string(), Value::F32(x))
            } else {
                let ids = ITensor {
                    shape: vec![16],
                    data: (0..16).map(|_| rng.below(64) as i32).collect(),
                };
                ("tf".to_string(), Value::I32(ids))
            }
        })
        .collect()
}

/// Assert `replies[i]` answers `records[i]`: right lane, and logits
/// bit-identical to an offline batch-of-1 forward of the record's
/// payload.  Payloads are distinct per record, so position identity is
/// also the FIFO / mis-route check.
fn assert_bit_identical(
    report: &efqat::serve::ReplayReport,
    records: &[ReplayRecord],
    mlp: &QuantizedGraph,
    tf: &QuantizedGraph,
    tag: &str,
) {
    assert_eq!(report.replies.len(), records.len(), "{tag}: replay dropped records");
    assert_eq!(report.lat_ms.len(), records.len(), "{tag}: missing latencies");
    for (i, (reply, rec)) in report.replies.iter().zip(records).enumerate() {
        assert_eq!(&*reply.model, rec.model.as_str(), "{tag}: record {i} mis-routed");
        let engine = if rec.model == "mlp" { mlp } else { tf };
        let want = engine.forward_owned(unit_batch(&rec.input)).unwrap();
        assert_eq!(reply.logits.data, want.data, "{tag}: record {i} diverged from offline eval");
    }
}

#[test]
fn recorded_trace_replays_bit_identically_at_1x_and_8x() {
    let dir = std::env::temp_dir().join("efqat_replay_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.jsonl");
    let path = path.to_str().unwrap();

    // live capture: a recorder attached to a two-model registry sees
    // every accepted submission with its arrival offset
    let (server, _mlp, _tf) = two_model_server(false);
    let rec = Arc::new(TrafficRecorder::create(path).unwrap());
    server.registry().set_recorder(rec.clone());
    let stream = traffic(40);
    let tickets: Vec<_> = stream
        .iter()
        .map(|(m, v)| server.try_submit(Some(m.as_str()), v.clone()).unwrap())
        .collect();
    for t in tickets {
        t.wait_reply().unwrap();
    }
    server.registry().flush_trace();
    assert_eq!(rec.records(), 40);
    server.shutdown();

    let records = load_trace(path).unwrap();
    assert_eq!(records.len(), 40, "recorder captured every accepted request");
    assert!(records.windows(2).all(|w| w[0].t_us <= w[1].t_us), "offsets must be ordered");
    assert!(records.iter().step_by(2).all(|r| r.model == "mlp"), "lane names captured wrong");

    // 1× with the static batcher, 8× with the adaptive batcher: the
    // replies must be bit-identical to offline eval either way, in
    // record order — speed and flush policy change scheduling only
    for (speed, adaptive) in [(1.0, false), (8.0, true)] {
        let (server, mlp, tf) = two_model_server(adaptive);
        let report = replay(&server, &records, speed).unwrap();
        let tag = format!("speed {speed} adaptive {adaptive}");
        assert_bit_identical(&report, &records, &mlp, &tf, &tag);
        server.shutdown();
    }
}

#[test]
fn replay_retries_overload_and_never_drops() {
    // a burst far larger than the lane (queue_cap 2, max_batch 1): the
    // replay driver must absorb `overloaded` verdicts by retrying, and
    // still answer every record in order
    let mlp = Arc::new(fixture("mlp"));
    let registry = Registry::new();
    registry.install("mlp", mlp.clone(), "fp-mlp").unwrap();
    let cfg = ServeCfg::builder()
        .max_batch(1)
        .max_wait_ms(0.0)
        .workers(1)
        .queue_cap(2)
        .build()
        .unwrap();
    let server = Server::start(registry, cfg).unwrap();

    let mut rng = efqat::rng::Pcg64::new(7);
    let records: Vec<ReplayRecord> = (0..32)
        .map(|_| ReplayRecord {
            t_us: 0, // all due immediately: maximum intake pressure
            model: "mlp".to_string(),
            input: Value::F32(Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) }),
        })
        .collect();
    let report = replay(&server, &records, 1000.0).unwrap();
    assert_eq!(report.replies.len(), 32, "overload must retry, not drop");
    for (i, (reply, rec)) in report.replies.iter().zip(&records).enumerate() {
        let want = mlp.forward_owned(unit_batch(&rec.input)).unwrap();
        assert_eq!(reply.logits.data, want.data, "record {i} diverged under overload");
    }
    server.shutdown();
}
