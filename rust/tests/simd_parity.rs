//! Differential oracle suite for the SIMD GEMM kernels — both the
//! int8 serving family and the f32 training family.
//!
//! Every int8 kernel the dispatch registry offers on this CPU must
//! agree with the scalar oracle (`kernels()[0]`) *bit-for-bit* —
//! identical i32 dot products and identical f32 GEMM outputs, not
//! merely close ones — over a seeded adversarial grid: contraction
//! lengths around each kernel's lane width (tails!), single-row
//! batches, output widths straddling the `par_rows` thread-split
//! boundary, every interesting zero point, all-saturated codes, and
//! empty inputs.  The end-to-end leg checks that whole-model serving
//! (logits and `evaluate_int8` metrics) is invariant under the
//! dispatch choice for all three native models.
//!
//! The f32 family (`kernels_f32()`) carries the weaker contract its
//! FMA kernels can honor: *tolerance*-equal to the scalar oracle
//! (≤ 1e-5 relative) but individually bit-deterministic — repeated
//! calls of one kernel, and repeated train steps under one forced
//! kernel, never differ by a bit.  The end-to-end leg runs a whole
//! quantized train step forced-scalar vs dispatched and checks the
//! loss agrees within tolerance.
//!
//! Dot-level checks call the kernel function pointers directly.  Tests
//! that exercise the *dispatched* path instead go through
//! [`efqat::ops::simd::force`] / [`efqat::ops::simd::force_f32`],
//! which are process-global state — those tests serialize on a mutex
//! so the harness's default parallelism cannot interleave forced
//! kernels.

use std::path::Path;
use std::sync::Mutex;

use efqat::backend::Value;
use efqat::cfg::Config;
use efqat::coordinator::evaluate_int8;
use efqat::coordinator::tasks::test_loader;
use efqat::coordinator::Session;
use efqat::graph::InputKind;
use efqat::lower::lower;
use efqat::model::{Dtype, Manifest, ParamStore};
use efqat::ops::qmatmul::{qlinear_fwd, I32_EXACT_MAX_K};
use efqat::ops::simd::{active, active_f32, force, force_f32, kernels, kernels_f32};
use efqat::rng::Pcg64;
use efqat::tensor::{ITensor, Tensor};
use efqat::testing::{fvec, rand_act_codes, rand_weight_codes, synth_lowering_fixture, wsum_rows};

/// Serializes every test that touches the process-global [`force`]
/// override.  Poisoning is recovered: a failed parity test must not
/// cascade into "poisoned lock" noise in the remaining tests.
static DISPATCH: Mutex<()> = Mutex::new(());

fn dispatch_lock() -> std::sync::MutexGuard<'static, ()> {
    DISPATCH.lock().unwrap_or_else(|e| e.into_inner())
}

/// The adversarial contraction lengths for a kernel: everything around
/// its lane width (empty, scalar tail only, one-short, exact, one-over,
/// a multi-vector run with a tail) plus a full cache block.
fn k_grid(lanes: usize) -> Vec<usize> {
    let mut ks = vec![0, 1, lanes.saturating_sub(1), lanes, lanes + 1, 3 * lanes + 2, 512];
    ks.sort_unstable();
    ks.dedup();
    ks
}

#[test]
fn dot_matches_scalar_oracle_on_adversarial_grid() {
    let ks = kernels();
    let oracle = ks[0].dot;
    for kern in ks {
        for klen in k_grid(kern.lanes) {
            // seeded random codes over the full domains, several draws
            let mut rng = Pcg64::new(0xd07 ^ klen as u64);
            for case in 0..8 {
                let x = rand_act_codes(&mut rng, klen);
                let w = rand_weight_codes(&mut rng, klen);
                assert_eq!((kern.dot)(&x, &w), oracle(&x, &w), "{} k={klen} c={case}", kern.name);
            }
            // all-saturated codes: the worst-magnitude products, where a
            // saturating i16 intermediate (maddubs-style) would clip
            let hi = vec![255u8; klen];
            for wv in [127i8, -127] {
                let w = vec![wv; klen];
                assert_eq!((kern.dot)(&hi, &w), oracle(&hi, &w), "{} k={klen} w={wv}", kern.name);
            }
            // alternating signs: partial cancellation across lanes
            let w: Vec<i8> = (0..klen).map(|i| if i % 2 == 0 { 127 } else { -127 }).collect();
            assert_eq!((kern.dot)(&hi, &w), oracle(&hi, &w), "{} k={klen} ±127", kern.name);
        }
    }
}

#[test]
fn dot_is_exact_at_the_i32_bound() {
    // at k = I32_EXACT_MAX_K with the worst-case codes the exact sum is
    // within a few products of i32::MIN — any kernel that widens wrong,
    // saturates, or mis-reconstructs the sdot sign trick breaks here
    let k = I32_EXACT_MAX_K;
    let x = vec![255u8; k];
    let w = vec![-127i8; k];
    let want = -(255i64 * 127 * k as i64);
    assert!(want >= i32::MIN as i64, "test premise: bound fits i32");
    for kern in kernels() {
        assert_eq!((kern.dot)(&x, &w), want as i32, "{}", kern.name);
    }
}

#[test]
fn gemm_outputs_bit_identical_across_kernels() {
    let _g = dispatch_lock();
    let ks = kernels();
    // n = 64 stays under the par_rows split at k=512/m=7; n = 160
    // crosses it — both sides of the threading boundary are covered
    for m in [1usize, 2, 7] {
        for klen in k_grid(ks.iter().map(|k| k.lanes).max().unwrap()) {
            for n in [1usize, 64, 160] {
                for zx in [0i32, 128, 255] {
                    let mut rng = Pcg64::new((m * 31 + klen * 7 + n) as u64 ^ zx as u64);
                    let qx = rand_act_codes(&mut rng, m * klen);
                    let qw = rand_weight_codes(&mut rng, n * klen);
                    let wsum = wsum_rows(&qw, n);
                    let scale: Vec<f32> = (0..n).map(|_| rng.uniform_in(1e-4, 1e-2)).collect();
                    let bias: Vec<f32> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();

                    force(Some(0));
                    let want = qlinear_fwd(&qx, &qw, &wsum, zx, &scale, Some(&bias), m, klen, n);
                    for idx in 1..ks.len() {
                        force(Some(idx));
                        let got = qlinear_fwd(&qx, &qw, &wsum, zx, &scale, Some(&bias), m, klen, n);
                        assert_eq!(got, want, "{} m={m} k={klen} n={n} zx={zx}", ks[idx].name);
                    }
                    force(None);
                }
            }
        }
    }
}

#[test]
fn empty_gemm_is_empty_under_every_kernel() {
    let _g = dispatch_lock();
    for idx in 0..kernels().len() {
        force(Some(idx));
        assert!(qlinear_fwd(&[], &[], &[], 0, &[], None, 0, 16, 0).is_empty());
        assert!(qlinear_fwd(&[], &[], &[], 128, &[], None, 0, 0, 0).is_empty());
        // m>0 with k=0: pure zero-point/bias path, no dot calls at all
        let y = qlinear_fwd(&[], &[], &[0, 0], 128, &[0.5, 0.5], None, 3, 0, 2);
        assert_eq!(y, vec![0.0; 6]);
        force(None);
    }
}

#[test]
fn serve_logits_and_eval_metrics_invariant_under_dispatch() {
    let _g = dispatch_lock();
    let ks = kernels();
    let auto = ks.len() - 1; // what EFQAT_SIMD=auto resolves to
    let mut cfg = Config::empty();
    cfg.set("data.train_n", "64");
    cfg.set("data.test_n", "64");
    cfg.set("data.calib_samples", "64");
    for model in ["mlp", "convnet", "tiny_tf"] {
        let (g, params, q) = synth_lowering_fixture(model);
        let qg = lower(&g, &params, &q, 8, 8).unwrap();
        let x = match g.input {
            InputKind::Image { channels, hw } => {
                let mut rng = Pcg64::new(0xe2e);
                Value::F32(Tensor {
                    shape: vec![4, channels, hw, hw],
                    data: rng.normal_vec(4 * channels * hw * hw, 1.0),
                })
            }
            InputKind::Tokens { seq } => {
                let data: Vec<i32> = (0..4 * seq).map(|j| (j as i32 * 13) % 64).collect();
                Value::I32(ITensor { shape: vec![4, seq], data })
            }
        };

        force(Some(0));
        assert_eq!(active().name, "scalar");
        let logits_off = qg.forward(&x).unwrap();
        let eval_off = evaluate_int8(&qg, &mut test_loader(model, 16, &cfg).unwrap()).unwrap();

        force(Some(auto));
        let logits_auto = qg.forward(&x).unwrap();
        let eval_auto = evaluate_int8(&qg, &mut test_loader(model, 16, &cfg).unwrap()).unwrap();
        force(None);

        assert_eq!(logits_off.shape, logits_auto.shape, "{model}");
        assert_eq!(
            logits_off.data, logits_auto.data,
            "{model}: serve logits differ between scalar and {}",
            ks[auto].name
        );
        assert_eq!(eval_off.n, eval_auto.n, "{model}");
        assert_eq!(eval_off.accuracy, eval_auto.accuracy, "{model}: accuracy drifted");
        assert_eq!(eval_off.loss, eval_auto.loss, "{model}: loss drifted");
    }
}

#[test]
fn forced_dispatch_reports_the_forced_kernel() {
    let _g = dispatch_lock();
    for (idx, kern) in kernels().iter().enumerate() {
        force(Some(idx));
        assert_eq!(active().name, kern.name);
    }
    force(None);
}

// ---------------------------------------------------------------- f32 family

/// Relative tolerance for vector-vs-scalar f32 comparisons.  FMA fuses
/// the multiply-add rounding and lane-parallel accumulation reorders
/// the sum, so vector kernels are not bit-equal to the strictly
/// sequential scalar oracle — but over these magnitudes they stay well
/// inside 1e-5 relative.
const F32_RTOL: f32 = 1e-5;

fn assert_close(got: f32, want: f32, ctx: &std::fmt::Arguments) {
    let tol = F32_RTOL * want.abs().max(1.0);
    assert!((got - want).abs() <= tol, "{ctx}: got {got}, want {want} (tol {tol})");
}

#[test]
fn f32_dot_and_axpy_match_scalar_oracle_within_tolerance() {
    let ks = kernels_f32();
    let oracle = ks[0];
    for kern in ks {
        for klen in k_grid(kern.lanes) {
            let mut rng = Pcg64::new(0xf32d07 ^ klen as u64);
            for case in 0..8 {
                let x = fvec(&mut rng, klen, -2.0, 2.0);
                let w = fvec(&mut rng, klen, -2.0, 2.0);
                assert_close(
                    (kern.dot)(&x, &w),
                    (oracle.dot)(&x, &w),
                    &format_args!("{} dot k={klen} c={case}", kern.name),
                );

                let a = rng.uniform_in(-3.0, 3.0);
                let mut y = fvec(&mut rng, klen, -1.0, 1.0);
                let mut y_want = y.clone();
                (kern.axpy)(a, &x, &mut y);
                (oracle.axpy)(a, &x, &mut y_want);
                for (i, (got, want)) in y.iter().zip(&y_want).enumerate() {
                    assert_close(
                        *got,
                        *want,
                        &format_args!("{} axpy k={klen} c={case} i={i}", kern.name),
                    );
                }
            }
            // partial cancellation: alternating-sign weights against a
            // constant vector stress the accumulation order hardest
            let x = vec![1.5f32; klen];
            let w: Vec<f32> = (0..klen).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
            assert_close(
                (kern.dot)(&x, &w),
                (oracle.dot)(&x, &w),
                &format_args!("{} dot k={klen} ±", kern.name),
            );
        }
    }
}

#[test]
fn f32_kernels_are_individually_bit_deterministic() {
    // the cross-kernel contract is tolerance-based, but each kernel on
    // its own must be a pure function: same inputs, same bits, every run
    for kern in kernels_f32() {
        for klen in k_grid(kern.lanes) {
            let mut rng = Pcg64::new(0xb17 ^ klen as u64);
            let x = fvec(&mut rng, klen, -2.0, 2.0);
            let w = fvec(&mut rng, klen, -2.0, 2.0);
            let first = (kern.dot)(&x, &w);
            for rep in 0..4 {
                let again = (kern.dot)(&x, &w);
                assert_eq!(
                    again.to_bits(),
                    first.to_bits(),
                    "{} dot k={klen} rep={rep} not deterministic",
                    kern.name
                );
            }

            let a = 1.25f32;
            let y0 = fvec(&mut rng, klen, -1.0, 1.0);
            let mut y_first = y0.clone();
            (kern.axpy)(a, &x, &mut y_first);
            for rep in 0..4 {
                let mut y = y0.clone();
                (kern.axpy)(a, &x, &mut y);
                let same = y.iter().zip(&y_first).all(|(p, q)| p.to_bits() == q.to_bits());
                assert!(same, "{} axpy k={klen} rep={rep} not deterministic", kern.name);
            }
        }
    }
}

/// Build valid inputs for a native train manifest without a dataset —
/// same recipe as the integration suite's generic inputs: initialized
/// params, sane qparams, seeded random images / zero token ids, first-k
/// index selections, and all freeze flags active.
fn train_inputs(man: &Manifest, params: &ParamStore, seed: u64) -> Vec<Value> {
    let mut rng = Pcg64::new(seed);
    man.inputs
        .iter()
        .map(|spec| match spec.role.as_str() {
            "param" => Value::F32(params.get(&spec.name).unwrap().clone()),
            "qparam_sw" => {
                Value::F32(Tensor { shape: spec.shape.clone(), data: vec![0.05; spec.elems()] })
            }
            "qparam_sx" => Value::F32(Tensor::scalar(0.05)),
            "qparam_zx" => Value::F32(Tensor::scalar(128.0)),
            "data" => match spec.dtype {
                Dtype::F32 => Value::F32(Tensor {
                    shape: spec.shape.clone(),
                    data: rng.normal_vec(spec.elems(), 1.0),
                }),
                // zeros are valid labels and valid token ids everywhere
                Dtype::I32 => Value::I32(ITensor::zeros(&spec.shape)),
            },
            "index" => Value::I32(ITensor {
                shape: spec.shape.clone(),
                data: (0..spec.shape[0] as i32).collect(),
            }),
            "flag" => Value::I32(ITensor { shape: vec![1], data: vec![1] }),
            other => panic!("unexpected input role {other:?}"),
        })
        .collect()
}

#[test]
fn train_step_loss_invariant_under_f32_dispatch() {
    let _g = dispatch_lock();
    let ks = kernels_f32();
    let auto = ks.len() - 1; // what EFQAT_SIMD=auto resolves to
    let s = Session::new(Path::new("artifacts")).expect("native session");
    for model in ["mlp", "convnet", "tiny_tf"] {
        let name = format!("{model}_w8a8_train_r25");
        let step = s.steps.get(&name).unwrap_or_else(|e| panic!("{name}: {e}"));
        let params = ParamStore::init(&step.manifest, 17);
        let inputs = train_inputs(&step.manifest, &params, 41);

        force_f32(Some(0));
        assert_eq!(active_f32().name, "scalar");
        let out_scalar = step.execute(&inputs).unwrap();

        force_f32(Some(auto));
        let out_auto = step.execute(&inputs).unwrap();
        let out_again = step.execute(&inputs).unwrap();
        force_f32(None);

        // whole-step loss: scalar vs dispatched.  Looser than the
        // kernel-level bound — a ~1e-6 FMA difference in a GEMM output
        // can flip a downstream fake-quant rounding decision by one
        // code, which moves the loss by far more than the raw kernel
        // error.  A genuinely wrong kernel misses by orders of
        // magnitude more than this.
        let (l0, l1) = (out_scalar.loss().unwrap(), out_auto.loss().unwrap());
        let tol = 5e-3 * l0.abs().max(1.0);
        assert!(
            (l1 - l0).abs() <= tol,
            "{name}: loss {l1} under {} vs scalar {l0} (tol {tol})",
            ks[auto].name
        );

        // under one fixed kernel the full train step is bit-reproducible
        for spec in &step.manifest.outputs {
            let (a, b) = (out_auto.get(&spec.name).unwrap(), out_again.get(&spec.name).unwrap());
            match (a, b) {
                (Value::F32(p), Value::F32(q)) => {
                    let same =
                        p.data.iter().zip(&q.data).all(|(x, y)| x.to_bits() == y.to_bits());
                    assert!(same, "{name}: {} not reproducible under {}", spec.name, ks[auto].name);
                }
                (Value::I32(p), Value::I32(q)) => {
                    assert_eq!(p.data, q.data, "{name}: {}", spec.name);
                }
                _ => panic!("{name}: {} dtype drift between runs", spec.name),
            }
        }
    }
}

#[test]
fn forced_f32_dispatch_reports_the_forced_kernel() {
    let _g = dispatch_lock();
    for (idx, kern) in kernels_f32().iter().enumerate() {
        force_f32(Some(idx));
        assert_eq!(active_f32().name, kern.name);
    }
    force_f32(None);
}
