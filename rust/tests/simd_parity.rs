//! Differential oracle suite for the SIMD int8 GEMM kernels.
//!
//! Every kernel the dispatch registry offers on this CPU must agree
//! with the scalar oracle (`kernels()[0]`) *bit-for-bit* — identical
//! i32 dot products and identical f32 GEMM outputs, not merely close
//! ones — over a seeded adversarial grid: contraction lengths around
//! each kernel's lane width (tails!), single-row batches, output widths
//! straddling the `par_rows` thread-split boundary, every interesting
//! zero point, all-saturated codes, and empty inputs.  The end-to-end
//! leg checks that whole-model serving (logits and `evaluate_int8`
//! metrics) is invariant under the dispatch choice for all three native
//! models.
//!
//! Dot-level checks call the kernel function pointers directly.  Tests
//! that exercise the *dispatched* path instead go through
//! [`efqat::ops::simd::force`], which is process-global state — those
//! tests serialize on a mutex so the harness's default parallelism
//! cannot interleave forced kernels.

use std::sync::Mutex;

use efqat::backend::Value;
use efqat::cfg::Config;
use efqat::coordinator::evaluate_int8;
use efqat::coordinator::tasks::test_loader;
use efqat::graph::InputKind;
use efqat::lower::lower;
use efqat::ops::qmatmul::{qlinear_fwd, I32_EXACT_MAX_K};
use efqat::ops::simd::{active, force, kernels};
use efqat::rng::Pcg64;
use efqat::tensor::{ITensor, Tensor};
use efqat::testing::{rand_act_codes, rand_weight_codes, synth_lowering_fixture, wsum_rows};

/// Serializes every test that touches the process-global [`force`]
/// override.  Poisoning is recovered: a failed parity test must not
/// cascade into "poisoned lock" noise in the remaining tests.
static DISPATCH: Mutex<()> = Mutex::new(());

fn dispatch_lock() -> std::sync::MutexGuard<'static, ()> {
    DISPATCH.lock().unwrap_or_else(|e| e.into_inner())
}

/// The adversarial contraction lengths for a kernel: everything around
/// its lane width (empty, scalar tail only, one-short, exact, one-over,
/// a multi-vector run with a tail) plus a full cache block.
fn k_grid(lanes: usize) -> Vec<usize> {
    let mut ks = vec![0, 1, lanes.saturating_sub(1), lanes, lanes + 1, 3 * lanes + 2, 512];
    ks.sort_unstable();
    ks.dedup();
    ks
}

#[test]
fn dot_matches_scalar_oracle_on_adversarial_grid() {
    let ks = kernels();
    let oracle = ks[0].dot;
    for kern in ks {
        for klen in k_grid(kern.lanes) {
            // seeded random codes over the full domains, several draws
            let mut rng = Pcg64::new(0xd07 ^ klen as u64);
            for case in 0..8 {
                let x = rand_act_codes(&mut rng, klen);
                let w = rand_weight_codes(&mut rng, klen);
                assert_eq!((kern.dot)(&x, &w), oracle(&x, &w), "{} k={klen} c={case}", kern.name);
            }
            // all-saturated codes: the worst-magnitude products, where a
            // saturating i16 intermediate (maddubs-style) would clip
            let hi = vec![255u8; klen];
            for wv in [127i8, -127] {
                let w = vec![wv; klen];
                assert_eq!((kern.dot)(&hi, &w), oracle(&hi, &w), "{} k={klen} w={wv}", kern.name);
            }
            // alternating signs: partial cancellation across lanes
            let w: Vec<i8> = (0..klen).map(|i| if i % 2 == 0 { 127 } else { -127 }).collect();
            assert_eq!((kern.dot)(&hi, &w), oracle(&hi, &w), "{} k={klen} ±127", kern.name);
        }
    }
}

#[test]
fn dot_is_exact_at_the_i32_bound() {
    // at k = I32_EXACT_MAX_K with the worst-case codes the exact sum is
    // within a few products of i32::MIN — any kernel that widens wrong,
    // saturates, or mis-reconstructs the sdot sign trick breaks here
    let k = I32_EXACT_MAX_K;
    let x = vec![255u8; k];
    let w = vec![-127i8; k];
    let want = -(255i64 * 127 * k as i64);
    assert!(want >= i32::MIN as i64, "test premise: bound fits i32");
    for kern in kernels() {
        assert_eq!((kern.dot)(&x, &w), want as i32, "{}", kern.name);
    }
}

#[test]
fn gemm_outputs_bit_identical_across_kernels() {
    let _g = dispatch_lock();
    let ks = kernels();
    // n = 64 stays under the par_rows split at k=512/m=7; n = 160
    // crosses it — both sides of the threading boundary are covered
    for m in [1usize, 2, 7] {
        for klen in k_grid(ks.iter().map(|k| k.lanes).max().unwrap()) {
            for n in [1usize, 64, 160] {
                for zx in [0i32, 128, 255] {
                    let mut rng = Pcg64::new((m * 31 + klen * 7 + n) as u64 ^ zx as u64);
                    let qx = rand_act_codes(&mut rng, m * klen);
                    let qw = rand_weight_codes(&mut rng, n * klen);
                    let wsum = wsum_rows(&qw, n);
                    let scale: Vec<f32> = (0..n).map(|_| rng.uniform_in(1e-4, 1e-2)).collect();
                    let bias: Vec<f32> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();

                    force(Some(0));
                    let want = qlinear_fwd(&qx, &qw, &wsum, zx, &scale, Some(&bias), m, klen, n);
                    for idx in 1..ks.len() {
                        force(Some(idx));
                        let got = qlinear_fwd(&qx, &qw, &wsum, zx, &scale, Some(&bias), m, klen, n);
                        assert_eq!(got, want, "{} m={m} k={klen} n={n} zx={zx}", ks[idx].name);
                    }
                    force(None);
                }
            }
        }
    }
}

#[test]
fn empty_gemm_is_empty_under_every_kernel() {
    let _g = dispatch_lock();
    for idx in 0..kernels().len() {
        force(Some(idx));
        assert!(qlinear_fwd(&[], &[], &[], 0, &[], None, 0, 16, 0).is_empty());
        assert!(qlinear_fwd(&[], &[], &[], 128, &[], None, 0, 0, 0).is_empty());
        // m>0 with k=0: pure zero-point/bias path, no dot calls at all
        let y = qlinear_fwd(&[], &[], &[0, 0], 128, &[0.5, 0.5], None, 3, 0, 2);
        assert_eq!(y, vec![0.0; 6]);
        force(None);
    }
}

#[test]
fn serve_logits_and_eval_metrics_invariant_under_dispatch() {
    let _g = dispatch_lock();
    let ks = kernels();
    let auto = ks.len() - 1; // what EFQAT_SIMD=auto resolves to
    let mut cfg = Config::empty();
    cfg.set("data.train_n", "64");
    cfg.set("data.test_n", "64");
    cfg.set("data.calib_samples", "64");
    for model in ["mlp", "convnet", "tiny_tf"] {
        let (g, params, q) = synth_lowering_fixture(model);
        let qg = lower(&g, &params, &q, 8, 8).unwrap();
        let x = match g.input {
            InputKind::Image { channels, hw } => {
                let mut rng = Pcg64::new(0xe2e);
                Value::F32(Tensor {
                    shape: vec![4, channels, hw, hw],
                    data: rng.normal_vec(4 * channels * hw * hw, 1.0),
                })
            }
            InputKind::Tokens { seq } => {
                let data: Vec<i32> = (0..4 * seq).map(|j| (j as i32 * 13) % 64).collect();
                Value::I32(ITensor { shape: vec![4, seq], data })
            }
        };

        force(Some(0));
        assert_eq!(active().name, "scalar");
        let logits_off = qg.forward(&x).unwrap();
        let eval_off = evaluate_int8(&qg, &mut test_loader(model, 16, &cfg).unwrap()).unwrap();

        force(Some(auto));
        let logits_auto = qg.forward(&x).unwrap();
        let eval_auto = evaluate_int8(&qg, &mut test_loader(model, 16, &cfg).unwrap()).unwrap();
        force(None);

        assert_eq!(logits_off.shape, logits_auto.shape, "{model}");
        assert_eq!(
            logits_off.data, logits_auto.data,
            "{model}: serve logits differ between scalar and {}",
            ks[auto].name
        );
        assert_eq!(eval_off.n, eval_auto.n, "{model}");
        assert_eq!(eval_off.accuracy, eval_auto.accuracy, "{model}: accuracy drifted");
        assert_eq!(eval_off.loss, eval_auto.loss, "{model}: loss drifted");
    }
}

#[test]
fn forced_dispatch_reports_the_forced_kernel() {
    let _g = dispatch_lock();
    for (idx, kern) in kernels().iter().enumerate() {
        force(Some(idx));
        assert_eq!(active().name, kern.name);
    }
    force(None);
}
