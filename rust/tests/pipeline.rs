//! End-to-end pipeline tests on the native backend: pretrain → PTQ →
//! EfQAT → eval on the `mlp` model, exercising `coordinator::pipeline`
//! exactly as the CLI/examples do — including all three freezing modes
//! (CWPL / CWPN / LWPN) — with no Python-built artifacts present.

use std::path::PathBuf;

use efqat::cfg::Config;
use efqat::coordinator::pipeline::{
    ensure_fp_checkpoint, fp_ckpt_path, load_quant_checkpoint, run_efqat_pipeline,
};
use efqat::coordinator::Session;

fn tiny_cfg(tag: &str) -> Config {
    let mut cfg = Config::empty();
    cfg.set("data.train_n", "256");
    cfg.set("data.test_n", "128");
    cfg.set("data.calib_samples", "128");
    cfg.set("train.epochs", "2");
    cfg.set("train.lr_w", "0.02");
    let dir = std::env::temp_dir().join(format!("efqat_pipe_{tag}"));
    cfg.set("ckpt_dir", dir.to_str().unwrap());
    cfg
}

#[test]
fn full_pipeline_end_to_end() {
    let cfg = tiny_cfg("e2e");
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
    let session = Session::from_cfg(&cfg).unwrap();

    // pretrain runs once, is idempotent afterwards
    ensure_fp_checkpoint(&session, &cfg, "mlp", 2).unwrap();
    assert!(fp_ckpt_path(&cfg, "mlp").exists());
    let mtime = std::fs::metadata(fp_ckpt_path(&cfg, "mlp")).unwrap().modified().unwrap();
    ensure_fp_checkpoint(&session, &cfg, "mlp", 2).unwrap();
    assert_eq!(
        mtime,
        std::fs::metadata(fp_ckpt_path(&cfg, "mlp")).unwrap().modified().unwrap(),
        "pretrain not idempotent"
    );

    let s = run_efqat_pipeline(&session, &cfg, "mlp", "w8a8", "cwpn", 25).unwrap();
    // EfQAT must not be (much) worse than PTQ, and losses must be finite
    assert!(s.losses.iter().all(|l| l.is_finite()));
    assert!(
        s.efqat_headline >= s.ptq_headline - 2.0,
        "EfQAT {} << PTQ {}",
        s.efqat_headline,
        s.ptq_headline
    );
    assert!(s.exec_seconds > 0.0);

    // quantized checkpoint written and loadable
    let ck = PathBuf::from(cfg.str("ckpt_dir", "")).join("mlp_w8a8_cwpn25.ckpt");
    let (p, _st, q) = load_quant_checkpoint(&ck).unwrap();
    assert!(!p.map.is_empty());
    assert_eq!(q.sw.len(), q.act.len());

    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
}

#[test]
fn every_efqat_mode_runs_through_the_native_backend() {
    // the acceptance path: PTQ init + one EfQAT epoch for each of the
    // paper's three policies, plus the QAT (r=100) and r=0 baselines
    let cfg = tiny_cfg("modes");
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
    let session = Session::from_cfg(&cfg).unwrap();
    ensure_fp_checkpoint(&session, &cfg, "mlp", 2).unwrap();
    for mode in ["cwpl", "cwpn", "lwpn", "qat", "r0"] {
        let s = run_efqat_pipeline(&session, &cfg, "mlp", "w8a8", mode, 25)
            .unwrap_or_else(|e| panic!("{mode}: {e}"));
        assert!(s.losses.iter().all(|l| l.is_finite()), "{mode}: non-finite loss");
        assert!(!s.losses.is_empty(), "{mode}: empty epoch");
    }
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
}

#[test]
fn convnet_runs_the_full_pipeline_in_every_mode() {
    // PTQ → CWPL/CWPN/LWPN/QAT/r0, natively, on conv-style WSites
    let mut cfg = tiny_cfg("convnet");
    cfg.set("train.lr_w", "0.01");
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
    let session = Session::from_cfg(&cfg).unwrap();
    ensure_fp_checkpoint(&session, &cfg, "convnet", 2).unwrap();
    for mode in ["cwpl", "cwpn", "lwpn", "qat", "r0"] {
        let s = run_efqat_pipeline(&session, &cfg, "convnet", "w8a8", mode, 25)
            .unwrap_or_else(|e| panic!("convnet/{mode}: {e}"));
        assert!(s.losses.iter().all(|l| l.is_finite()), "convnet/{mode}: non-finite loss");
        assert!(!s.losses.is_empty(), "convnet/{mode}: empty epoch");
        assert!(
            s.efqat_headline >= s.ptq_headline - 10.0,
            "convnet/{mode}: EfQAT {} collapsed vs PTQ {}",
            s.efqat_headline,
            s.ptq_headline
        );
    }
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
}

#[test]
fn tiny_tf_runs_the_full_pipeline_in_every_mode() {
    // the paper's transformer shape: embed → attention → MLP block, with
    // all seven projection sites quantized and freezable
    let mut cfg = tiny_cfg("tiny_tf");
    cfg.set("train.lr_w", "0.01");
    cfg.set("data.train_tokens", "4096");
    cfg.set("data.test_tokens", "1024");
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
    let session = Session::from_cfg(&cfg).unwrap();
    ensure_fp_checkpoint(&session, &cfg, "tiny_tf", 2).unwrap();
    for mode in ["cwpl", "cwpn", "lwpn", "qat", "r0"] {
        let s = run_efqat_pipeline(&session, &cfg, "tiny_tf", "w8a8", mode, 25)
            .unwrap_or_else(|e| panic!("tiny_tf/{mode}: {e}"));
        assert!(s.losses.iter().all(|l| l.is_finite()), "tiny_tf/{mode}: non-finite loss");
        assert!(!s.losses.is_empty(), "tiny_tf/{mode}: empty epoch");
    }
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
}

#[test]
fn lwpn_pipeline_respects_budget() {
    let cfg = tiny_cfg("lwpn");
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
    let session = Session::from_cfg(&cfg).unwrap();
    ensure_fp_checkpoint(&session, &cfg, "mlp", 1).unwrap();
    let s = run_efqat_pipeline(&session, &cfg, "mlp", "w8a8", "lwpn", 10).unwrap();
    assert!(s.losses.iter().all(|l| l.is_finite()));
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
}

#[test]
fn lower_precision_also_runs() {
    // w4a8: same pipeline, coarser weight grid — exercises the bits
    // plumbing end-to-end on the native backend
    let cfg = tiny_cfg("w4a8");
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
    let session = Session::from_cfg(&cfg).unwrap();
    ensure_fp_checkpoint(&session, &cfg, "mlp", 1).unwrap();
    let s = run_efqat_pipeline(&session, &cfg, "mlp", "w4a8", "cwpl", 50).unwrap();
    assert!(s.losses.iter().all(|l| l.is_finite()));
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
}
