//! End-to-end pipeline test: pretrain → PTQ → EfQAT → eval on resnet8,
//! exercising `coordinator::pipeline` exactly as the CLI/examples do.

use std::path::{Path, PathBuf};

use efqat::cfg::Config;
use efqat::coordinator::pipeline::{
    ensure_fp_checkpoint, fp_ckpt_path, load_quant_checkpoint, run_efqat_pipeline,
};
use efqat::coordinator::Session;

fn artifacts_dir() -> PathBuf {
    for c in ["artifacts", "../artifacts"] {
        if Path::new(c).join("resnet8_fp_train.hlo.txt").exists() {
            return PathBuf::from(c);
        }
    }
    panic!("artifacts not found — run `make artifacts` first");
}

fn tiny_cfg(tag: &str) -> Config {
    let mut cfg = Config::empty();
    cfg.set("data.train_n", "512");
    cfg.set("data.test_n", "256");
    cfg.set("data.calib_samples", "128");
    cfg.set("train.epochs", "2");
    cfg.set("train.lr_w", "0.03");
    let dir = std::env::temp_dir().join(format!("efqat_pipe_{tag}"));
    cfg.set("ckpt_dir", dir.to_str().unwrap());
    cfg
}

#[test]
fn full_pipeline_end_to_end() {
    let cfg = tiny_cfg("e2e");
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
    let session = Session::new(&artifacts_dir()).unwrap();

    // pretrain runs once, is idempotent afterwards
    ensure_fp_checkpoint(&session, &cfg, "resnet8", 2).unwrap();
    assert!(fp_ckpt_path(&cfg, "resnet8").exists());
    let mtime = std::fs::metadata(fp_ckpt_path(&cfg, "resnet8")).unwrap().modified().unwrap();
    ensure_fp_checkpoint(&session, &cfg, "resnet8", 2).unwrap();
    assert_eq!(
        mtime,
        std::fs::metadata(fp_ckpt_path(&cfg, "resnet8")).unwrap().modified().unwrap(),
        "pretrain not idempotent"
    );

    let s = run_efqat_pipeline(&session, &cfg, "resnet8", "w8a8", "cwpn", 25).unwrap();
    // EfQAT must not be (much) worse than PTQ, and losses must be finite
    assert!(s.losses.iter().all(|l| l.is_finite()));
    assert!(
        s.efqat_headline >= s.ptq_headline - 2.0,
        "EfQAT {} << PTQ {}",
        s.efqat_headline,
        s.ptq_headline
    );
    assert!(s.exec_seconds > 0.0);

    // quantized checkpoint written and loadable
    let ck = PathBuf::from(cfg.str("ckpt_dir", "")).join("resnet8_w8a8_cwpn25.ckpt");
    let (p, st, q) = load_quant_checkpoint(&ck).unwrap();
    assert!(!p.map.is_empty() && !st.map.is_empty());
    assert_eq!(q.sw.len(), q.act.len());

    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
}

#[test]
fn lwpn_pipeline_respects_budget() {
    let cfg = tiny_cfg("lwpn");
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
    let session = Session::new(&artifacts_dir()).unwrap();
    ensure_fp_checkpoint(&session, &cfg, "resnet8", 1).unwrap();
    let s = run_efqat_pipeline(&session, &cfg, "resnet8", "w8a8", "lwpn", 10).unwrap();
    assert!(s.losses.iter().all(|l| l.is_finite()));
    std::fs::remove_dir_all(cfg.str("ckpt_dir", "")).ok();
}
