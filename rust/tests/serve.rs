//! Serving-runtime integration tests: the acceptance bar is that a
//! request answered through the concurrent batched path carries
//! **bit-identical** logits to the same example scored by offline
//! `--exec int8` eval — micro-batching is a latency/throughput lever,
//! never an accuracy one.
//!
//! Also covered here: deadline flush with a partial batch, routing to
//! the correct submitter under concurrency, token-model validation at
//! submission, the f32 reference engine, drain-on-shutdown, the JSONL
//! protocol end-to-end through `serve_stream` (v2 model routing, v1
//! fallback to the default model, stats introspection), and the
//! registry's hot-swap/admission-control contract: checkpoint swaps
//! under live two-model load drop nothing and mis-route nothing, a full
//! lane rejects with the typed `overloaded` code, and a retiring model
//! drains everything it accepted.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use efqat::backend::native::model_graph;
use efqat::backend::Value;
use efqat::cfg::Config;
use efqat::coordinator::tasks::test_loader;
use efqat::coordinator::{evaluate_int8, example_inputs};
use efqat::error::Result;
use efqat::graph::InputKind;
use efqat::json::Json;
use efqat::lower::{lower, QuantizedGraph};
use efqat::model::{ParamStore, QParamStore};
use efqat::serve::{BatchCfg, Engine, FloatEngine, Registry, Server, ServeCfg};
use efqat::tensor::{ITensor, Tensor};

/// The shared synthetic lowering fixture, pre-lowered: real weights from
/// the init distribution, mid-grid activation qparams.
fn fixture(model: &str) -> (QuantizedGraph, ParamStore, QParamStore) {
    let (g, params, q) = efqat::testing::synth_lowering_fixture(model);
    let qg = lower(&g, &params, &q, 8, 8).unwrap();
    (qg, params, q)
}

/// A lowered graph at a chosen init seed: same architecture, different
/// weights — a stand-in for a later training checkpoint of one model.
fn fixture_seeded(model: &str, seed: u64) -> QuantizedGraph {
    let (g, params, q) = efqat::testing::synth_lowering_fixture_seeded(model, seed);
    lower(&g, &params, &q, 8, 8).unwrap()
}

fn serve_cfg(max_batch: usize, wait: Duration, workers: usize) -> ServeCfg {
    let batch = BatchCfg { max_batch, max_wait: wait, adaptive: false };
    ServeCfg { batch, workers, queue_cap: 256 }
}

/// Re-shape one example into a batch of 1 — the single-request reference
/// every batched answer must be bit-identical to.
fn unit_batch(v: &Value) -> Value {
    match v {
        Value::F32(t) => {
            let mut shape = vec![1];
            shape.extend_from_slice(&t.shape);
            Value::F32(Tensor { shape, data: t.data.clone() })
        }
        Value::I32(t) => {
            let mut shape = vec![1];
            shape.extend_from_slice(&t.shape);
            Value::I32(ITensor { shape, data: t.data.clone() })
        }
    }
}

fn logits_of(doc: &Json) -> Vec<f32> {
    doc.get("logits").unwrap().arr().unwrap().iter().map(|j| j.num().unwrap() as f32).collect()
}

#[test]
fn batched_serving_is_bit_identical_to_int8_eval() {
    // the same loader drives offline eval and the request stream
    let (qg, _, _) = fixture("mlp");
    let cfg = Config::empty();
    let mut loader = test_loader("mlp", 32, &cfg).unwrap();
    let eval = evaluate_int8(&qg, &mut loader).unwrap();
    assert!(eval.n > 0);

    let engine = Arc::new(fixture("mlp").0);
    let server = Server::single(engine.clone(), serve_cfg(16, Duration::from_millis(1), 2));
    let mut loader = test_loader("mlp", 32, &cfg).unwrap();
    loader.reset();
    let mut checked = 0usize;
    while let Some(batch) = loader.next_batch() {
        let examples = example_inputs(engine.input, &batch).unwrap();
        // single-request reference: a batch-of-1 forward per example
        let singles: Vec<Tensor> =
            examples.iter().map(|v| engine.forward_owned(unit_batch(v)).unwrap()).collect();
        let tickets: Vec<_> = examples.into_iter().map(|v| server.submit(v).unwrap()).collect();
        for (t, want) in tickets.into_iter().zip(singles) {
            let got = t.wait().unwrap();
            assert_eq!(got.data, want.data, "batched logits diverged from batch-of-1");
            checked += 1;
        }
    }
    assert_eq!(checked, eval.n, "served exactly the examples eval scored");
    server.shutdown();
}

#[test]
fn worker_workspace_survives_batch_resizing_bit_identically() {
    // one worker, waves of different sizes: the worker's reused
    // workspace sees the dynamic batch grow, shrink, and regrow; every
    // answer must still be bit-identical to a fresh-allocation forward
    let engine = Arc::new(fixture("mlp").0);
    let server = Server::single(engine.clone(), serve_cfg(64, Duration::from_millis(1), 1));
    let mut rng = efqat::rng::Pcg64::new(77);
    for (wave, &count) in [4usize, 17, 1, 9, 33, 2].iter().enumerate() {
        let examples: Vec<Tensor> = (0..count)
            .map(|_| Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) })
            .collect();
        let tickets: Vec<_> = examples
            .iter()
            .map(|x| server.submit(Value::F32(x.clone())).unwrap())
            .collect();
        for (x, t) in examples.iter().zip(tickets) {
            let got = t.wait().unwrap();
            let want = engine
                .forward(&Value::F32(Tensor { shape: vec![1, 3, 8, 8], data: x.data.clone() }))
                .unwrap();
            assert_eq!(got.data, want.data, "wave {wave} (count {count})");
        }
    }
    server.shutdown();
}

#[test]
fn concurrent_submitters_get_their_own_logits() {
    let engine = Arc::new(fixture("mlp").0);
    let server = Server::single(engine.clone(), serve_cfg(8, Duration::from_millis(1), 3));
    std::thread::scope(|s| {
        for t in 0..6u64 {
            let (server, engine) = (&server, &engine);
            s.spawn(move || {
                let mut rng = efqat::rng::Pcg64::new(100 + t);
                for _ in 0..40 {
                    let x = Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) };
                    let want = engine
                        .forward(&Value::F32(Tensor {
                            shape: vec![1, 3, 8, 8],
                            data: x.data.clone(),
                        }))
                        .unwrap();
                    let got = server.submit(Value::F32(x)).unwrap().wait().unwrap();
                    // distinct random inputs per submitter: any misrouted
                    // response would fail this equality
                    assert_eq!(got.data, want.data, "response routed to the wrong request");
                }
            });
        }
    });
    server.shutdown();
}

#[test]
fn deadline_flushes_partial_batches() {
    let engine = Arc::new(fixture("mlp").0);
    // max_batch far above the offered load: only the deadline can flush
    let server = Server::single(engine, serve_cfg(1024, Duration::from_millis(10), 1));
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..3)
        .map(|_| server.submit(Value::F32(Tensor::zeros(&[3, 8, 8]))).unwrap())
        .collect();
    for t in tickets {
        assert_eq!(t.wait().unwrap().shape, vec![10]);
    }
    let waited = t0.elapsed();
    assert!(waited >= Duration::from_millis(10), "flushed before the deadline: {waited:?}");
    assert!(waited < Duration::from_secs(10), "deadline flush did not engage");
    server.shutdown();
}

#[test]
fn token_model_serves_and_validates_ids() {
    let engine = Arc::new(fixture("tiny_tf").0);
    let server = Server::single(engine.clone(), serve_cfg(4, Duration::from_millis(1), 2));
    let ids = ITensor { shape: vec![16], data: (0..16).map(|i| i % 64).collect() };
    let want = engine
        .forward(&Value::I32(ITensor { shape: vec![1, 16], data: ids.data.clone() }))
        .unwrap();
    let got = server.submit(Value::I32(ids)).unwrap().wait().unwrap();
    assert_eq!(got.shape, vec![16, 64]);
    assert_eq!(got.data, want.data);
    // an out-of-vocab id is rejected at submit — it never joins a batch
    let bad = ITensor { shape: vec![16], data: vec![99; 16] };
    let err = server.submit(Value::I32(bad)).unwrap_err().to_string();
    assert!(err.contains("out of range"), "{err}");
    server.shutdown();
}

#[test]
fn f32_engine_serves_within_fakequant_tolerance() {
    let (qg, params, q) = fixture("convnet");
    let engine = Arc::new(FloatEngine::new(
        model_graph("convnet").unwrap(),
        params,
        Some(q),
        8,
        8,
    ));
    let server = Server::single(engine, serve_cfg(4, Duration::from_millis(1), 1));
    let mut rng = efqat::rng::Pcg64::new(5);
    // odd request count: exercises a partial trailing batch in f32 too
    let examples: Vec<Tensor> =
        (0..5).map(|_| Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) }).collect();
    let tickets: Vec<_> = examples
        .iter()
        .map(|x| server.submit(Value::F32(x.clone())).unwrap())
        .collect();
    for (x, t) in examples.iter().zip(tickets) {
        let got = t.wait().unwrap();
        let int8 = qg
            .forward(&Value::F32(Tensor { shape: vec![1, 3, 8, 8], data: x.data.clone() }))
            .unwrap();
        // f32 vs int8 agree to the lowering tolerance (int8_parity bar)
        for (a, b) in got.data.iter().zip(&int8.data) {
            assert!((a - b).abs() <= 1e-3 * a.abs().max(1.0), "{a} vs {b}");
        }
    }
    server.shutdown();
}

#[test]
fn jsonl_stream_round_trips_bit_identically() {
    let engine = Arc::new(fixture("mlp").0);
    let server = Server::single(engine.clone(), serve_cfg(8, Duration::from_millis(1), 2));
    let mut rng = efqat::rng::Pcg64::new(11);
    let examples: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(192, 1.0)).collect();
    let mut input = String::new();
    for (i, ex) in examples.iter().enumerate() {
        let nums: Vec<String> = ex.iter().map(|v| format!("{}", *v as f64)).collect();
        input.push_str(&format!("{{\"id\": {i}, \"data\": [{}]}}\n", nums.join(",")));
    }
    input.push_str("{\"id\": \"bad\", \"data\": [1, 2]}\n"); // wrong length → error line

    let mut out: Vec<u8> = Vec::new();
    let n = efqat::serve::protocol::serve_stream(&server, input.as_bytes(), &mut out).unwrap();
    assert_eq!(n, 5);
    let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().trim().lines().collect();
    assert_eq!(lines.len(), 5);
    // FIFO responses: line i answers request i
    for (i, ex) in examples.iter().enumerate() {
        let doc = Json::parse(lines[i]).unwrap();
        assert_eq!(doc.get("id").unwrap().num().unwrap() as usize, i);
        // the v2 envelope names the engine that answered
        assert_eq!(doc.get("model").unwrap().str().unwrap(), "mlp");
        assert_eq!(doc.get("fp").unwrap().str().unwrap(), "unversioned");
        assert_eq!(doc.get("gen").unwrap().num().unwrap() as u64, 1);
        let want = engine
            .forward(&Value::F32(Tensor { shape: vec![1, 3, 8, 8], data: ex.clone() }))
            .unwrap();
        // f64 text round-trip is exact for f32 values
        assert_eq!(logits_of(&doc), want.data, "request {i}");
    }
    let err = Json::parse(lines[4]).unwrap();
    assert_eq!(err.get("id").unwrap().str().unwrap(), "bad");
    assert_eq!(err.get("code").unwrap().str().unwrap(), "bad_request");
    assert!(err.get("error").unwrap().str().unwrap().contains("2 elements"));
    server.shutdown();
}

#[test]
fn shutdown_answers_everything_accepted() {
    let engine = Arc::new(fixture("mlp").0);
    // huge batch + long wait: shutdown itself must force the drain
    let server = Server::single(engine, serve_cfg(512, Duration::from_secs(30), 2));
    let tickets: Vec<_> = (0..40)
        .map(|i| {
            let mut rng = efqat::rng::Pcg64::new(i);
            let x = Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) };
            server.submit(Value::F32(x)).unwrap()
        })
        .collect();
    server.shutdown();
    for t in tickets {
        assert_eq!(t.wait().unwrap().shape, vec![10], "request dropped during shutdown");
    }
}

#[test]
fn hot_swap_under_load_is_lossless_and_bit_identical() {
    // four successive "checkpoints" of one architecture: same serving
    // contract, different weights — distinguishable by their logits
    let gens: Vec<Arc<QuantizedGraph>> =
        (1..=4).map(|seed| Arc::new(fixture_seeded("mlp", seed))).collect();
    let right = Arc::new(fixture("tiny_tf").0);
    let mut engines: BTreeMap<String, Arc<QuantizedGraph>> = BTreeMap::new();
    for (i, g) in gens.iter().enumerate() {
        engines.insert(format!("fp-gen{}", i + 1), g.clone());
    }
    engines.insert("fp-right".to_string(), right.clone());

    let registry = Registry::new();
    registry.install("left", gens[0].clone(), "fp-gen1").unwrap();
    registry.install("right", right.clone(), "fp-right").unwrap();
    let server = Server::start(registry, serve_cfg(4, Duration::from_millis(1), 2)).unwrap();

    let done = AtomicUsize::new(0);
    let fps_seen = Mutex::new(BTreeSet::new());
    std::thread::scope(|s| {
        // three submitters hammer "left" (the lane being swapped) ...
        for t in 0..3u64 {
            let (server, engines, done, fps_seen) = (&server, &engines, &done, &fps_seen);
            s.spawn(move || {
                let mut rng = efqat::rng::Pcg64::new(500 + t);
                for i in 0..80 {
                    let x = Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) };
                    let reply = server
                        .try_submit(Some("left"), Value::F32(x.clone()))
                        .unwrap_or_else(|e| panic!("left request {i} bounced: {e}"))
                        .wait_reply()
                        .unwrap_or_else(|e| panic!("left request {i} dropped: {e}"));
                    assert_eq!(&*reply.model, "left");
                    // the reply names the engine that computed it — an
                    // in-flight request swapped over mid-queue must still
                    // match the graph its fingerprint claims, bit for bit
                    let engine = engines
                        .get(&*reply.fingerprint)
                        .unwrap_or_else(|| panic!("unknown fingerprint {}", reply.fingerprint));
                    let want = engine.forward_owned(unit_batch(&Value::F32(x))).unwrap();
                    assert_eq!(reply.logits.data, want.data, "mis-routed to the wrong graph");
                    fps_seen.lock().unwrap().insert(reply.fingerprint.to_string());
                    done.fetch_add(1, Ordering::SeqCst);
                }
            });
        }
        // ... two submitters ride "right", which swaps on "left" must
        // never perturb
        for t in 0..2u64 {
            let (server, right) = (&server, &right);
            s.spawn(move || {
                let mut rng = efqat::rng::Pcg64::new(900 + t);
                for _ in 0..40 {
                    let ids = ITensor {
                        shape: vec![16],
                        data: (0..16).map(|_| rng.below(64) as i32).collect(),
                    };
                    let reply = server
                        .try_submit(Some("right"), Value::I32(ids.clone()))
                        .unwrap()
                        .wait_reply()
                        .unwrap();
                    assert_eq!(&*reply.fingerprint, "fp-right");
                    assert_eq!(reply.generation, 1);
                    let want = right.forward_owned(unit_batch(&Value::I32(ids))).unwrap();
                    assert_eq!(reply.logits.data, want.data);
                }
            });
        }
        // three swaps land while both lanes are under live load, each
        // gated on real progress so requests straddle every swap
        let (server, done, gens) = (&server, &done, &gens);
        s.spawn(move || {
            for (i, fp) in ["fp-gen2", "fp-gen3", "fp-gen4"].iter().enumerate() {
                while done.load(Ordering::SeqCst) < (i + 1) * 40 {
                    std::thread::sleep(Duration::from_millis(1));
                }
                server.registry().install("left", gens[i + 1].clone(), fp).unwrap();
            }
        });
    });

    let fps = fps_seen.into_inner().unwrap();
    assert!(fps.contains("fp-gen1"), "pre-swap generation never answered: {fps:?}");
    assert!(fps.iter().all(|f| engines.contains_key(f)), "unknown fingerprints seen: {fps:?}");
    let slot = server.registry().engine_for(Some("left")).unwrap();
    assert_eq!((&*slot.fingerprint, slot.generation), ("fp-gen4", 4));

    // post-swap: the lane answers from the new checkpoint, bit-identical
    // to its offline `--exec int8` eval over the full test set
    let cfg = Config::empty();
    let mut loader = test_loader("mlp", 32, &cfg).unwrap();
    let eval = evaluate_int8(&gens[3], &mut loader).unwrap();
    assert!(eval.n > 0);
    let mut loader = test_loader("mlp", 32, &cfg).unwrap();
    loader.reset();
    let mut checked = 0usize;
    while let Some(batch) = loader.next_batch() {
        let examples = example_inputs(gens[3].input, &batch).unwrap();
        let singles: Vec<Tensor> =
            examples.iter().map(|v| gens[3].forward_owned(unit_batch(v)).unwrap()).collect();
        let tickets: Vec<_> = examples
            .into_iter()
            .map(|v| server.try_submit(Some("left"), v).unwrap())
            .collect();
        for (t, want) in tickets.into_iter().zip(singles) {
            let reply = t.wait_reply().unwrap();
            assert_eq!(&*reply.fingerprint, "fp-gen4");
            assert_eq!(reply.generation, 4);
            assert_eq!(reply.logits.data, want.data, "post-swap diverged from offline eval");
            checked += 1;
        }
    }
    assert_eq!(checked, eval.n, "served exactly the examples eval scored");
    server.shutdown();
}

#[test]
fn stats_expose_trace_percentiles_and_batch_fill() {
    let engine = Arc::new(fixture("mlp").0);
    let server = Server::single(engine, serve_cfg(8, Duration::from_millis(1), 1));
    let mut rng = efqat::rng::Pcg64::new(51);
    let tickets: Vec<_> = (0..24)
        .map(|_| {
            let x = Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) };
            server.submit(Value::F32(x)).unwrap()
        })
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let stats = server.stats();
    let st = stats.first().unwrap();
    let tr = st.trace.as_ref().expect("a started lane publishes trace stats");
    assert_eq!(tr.events, 24, "every answered request is one trace event");
    assert!((1..=24).contains(&tr.batches), "batches {}", tr.batches);
    assert!((1.0..=8.0).contains(&tr.mean_batch), "mean_batch {}", tr.mean_batch);
    assert!(st.batch_fill > 0.0 && st.batch_fill <= 1.0, "fill {}", st.batch_fill);
    // total = queue + batch + exec per event, and the histogram estimate
    // is monotone, so the total percentile dominates every stage's
    assert!(tr.total.p95_us >= tr.queue.p95_us, "{tr:?}");
    assert!(tr.total.p95_us >= tr.exec.p95_us, "{tr:?}");
    server.shutdown();
}

/// An engine whose forwards block until the test opens a gate — makes
/// "worker busy, lane backed up" states deterministic for the admission
/// control and draining tests.
struct GatedEngine {
    inner: QuantizedGraph,
    gate: Arc<(Mutex<bool>, Condvar)>,
}

fn gate() -> Arc<(Mutex<bool>, Condvar)> {
    Arc::new((Mutex::new(false), Condvar::new()))
}

fn open_gate(g: &Arc<(Mutex<bool>, Condvar)>) {
    *g.0.lock().unwrap() = true;
    g.1.notify_all();
}

impl Engine for GatedEngine {
    fn model(&self) -> &str {
        &self.inner.model
    }

    fn input(&self) -> InputKind {
        self.inner.input
    }

    fn classes(&self) -> usize {
        self.inner.classes
    }

    fn vocab(&self) -> Option<usize> {
        self.inner.vocab()
    }

    fn forward_batch(&self, x: Value) -> Result<Tensor> {
        let (flag, cv) = &*self.gate;
        let mut open = flag.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        drop(open);
        self.inner.forward_owned(x)
    }
}

#[test]
fn overload_rejects_with_typed_code_and_keeps_accepted_work() {
    let g = gate();
    let engine = Arc::new(GatedEngine { inner: fixture("mlp").0, gate: g.clone() });
    // the smallest possible lane: every stage behind the intake is gated,
    // so sustained submission must hit the 2-slot intake's admission edge
    let cfg = ServeCfg::builder()
        .max_batch(1)
        .max_wait_ms(0.0)
        .workers(1)
        .queue_cap(2)
        .build()
        .unwrap();
    let server = Server::single(engine, cfg);
    let mut rng = efqat::rng::Pcg64::new(21);
    let mut tickets = Vec::new();
    let mut rejected = None;
    for _ in 0..64 {
        let x = Value::F32(Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) });
        match server.try_submit(None, x) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                rejected = Some(e);
                break;
            }
        }
    }
    let e = rejected.expect("a gated worker behind a 2-slot queue must overload within 64 submits");
    assert_eq!(e.code(), "overloaded");
    let msg = e.to_string();
    assert!(msg.contains("intake queue full"), "{msg}");
    // the typed verdict converts to a plain error carrying its code
    let as_err: efqat::error::Error = e.into();
    assert!(as_err.to_string().contains("[overloaded]"), "{as_err}");
    // overload rejected the margin, never the accepted work
    open_gate(&g);
    for t in tickets {
        assert_eq!(t.wait().unwrap().shape, vec![10], "accepted request lost to overload");
    }
    server.shutdown();
}

#[test]
fn retire_reports_draining_then_drains_and_removes_the_model() {
    let g = gate();
    let engine = Arc::new(GatedEngine { inner: fixture("mlp").0, gate: g.clone() });
    let server = Server::single(engine, serve_cfg(4, Duration::from_millis(1), 1));
    let mut rng = efqat::rng::Pcg64::new(31);
    let mut image = || Value::F32(Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) });
    let tickets: Vec<_> =
        (0..6).map(|_| server.try_submit(Some("mlp"), image()).unwrap()).collect();
    std::thread::scope(|s| {
        let registry = server.registry().clone();
        let retire = s.spawn(move || registry.retire("mlp"));
        // the gate holds the drain open: the draining window is
        // observable for as long as this test needs it to be
        let t0 = Instant::now();
        while !server.stats().first().is_some_and(|m| m.draining) {
            assert!(t0.elapsed() < Duration::from_secs(10), "draining flag never became visible");
            std::thread::sleep(Duration::from_millis(1));
        }
        match server.try_submit(Some("mlp"), image()) {
            Err(e) => assert_eq!(e.code(), "draining"),
            Ok(_) => panic!("accepted a request while draining"),
        }
        open_gate(&g);
        retire.join().unwrap().unwrap();
    });
    // everything accepted before the retire was answered by the
    // outgoing engine ...
    for t in tickets {
        assert_eq!(t.wait().unwrap().shape, vec![10], "request dropped during retire");
    }
    // ... and the name is gone afterwards
    match server.try_submit(Some("mlp"), image()) {
        Err(e) => assert_eq!(e.code(), "unknown_model"),
        Ok(_) => panic!("retired model still serving"),
    }
    server.shutdown();
}

#[test]
fn stream_routes_v2_falls_back_v1_and_reports_stats() {
    let mlp = Arc::new(fixture("mlp").0);
    let convnet = Arc::new(fixture("convnet").0);
    let registry = Registry::new();
    registry.install("mlp", mlp.clone(), "fp-mlp-0123456789abcdef").unwrap();
    registry.install("convnet", convnet.clone(), "fp-convnet").unwrap();
    let server = Server::start(registry, serve_cfg(8, Duration::from_millis(1), 2)).unwrap();

    let mut rng = efqat::rng::Pcg64::new(41);
    let ex: Vec<f32> = rng.normal_vec(192, 1.0);
    let nums: Vec<String> = ex.iter().map(|v| format!("{}", *v as f64)).collect();
    let body = nums.join(",");
    let mut input = String::new();
    // 1: a v1 client names no model — the default model answers
    input.push_str(&format!("{{\"id\": 1, \"v\": 1, \"data\": [{body}]}}\n"));
    // 2: v2 routes by name
    input.push_str(&format!("{{\"id\": 2, \"model\": \"convnet\", \"data\": [{body}]}}\n"));
    // 3: unknown model → the registry's typed code on the wire
    input.push_str(&format!("{{\"id\": 3, \"model\": \"ghost\", \"data\": [{body}]}}\n"));
    // 4: a v1 request cannot name a model (v2-only grammar)
    input.push_str(&format!("{{\"id\": 4, \"v\": 1, \"model\": \"mlp\", \"data\": [{body}]}}\n"));
    // 5: stats introspection rides the same stream, FIFO preserved
    input.push_str("{\"id\": 5, \"stats\": true}\n");

    let mut out: Vec<u8> = Vec::new();
    let n = efqat::serve::protocol::serve_stream(&server, input.as_bytes(), &mut out).unwrap();
    assert_eq!(n, 5);
    let lines: Vec<Json> = std::str::from_utf8(&out)
        .unwrap()
        .trim()
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .collect();
    assert_eq!(lines.len(), 5);
    for (i, doc) in lines.iter().enumerate() {
        assert_eq!(doc.get("id").unwrap().num().unwrap() as usize, i + 1, "FIFO order broken");
    }
    let x = Value::F32(Tensor { shape: vec![1, 3, 8, 8], data: ex.clone() });
    assert_eq!(lines[0].get("model").unwrap().str().unwrap(), "mlp");
    // per-reply envelopes abbreviate the fingerprint to 12 chars
    assert_eq!(lines[0].get("fp").unwrap().str().unwrap(), "fp-mlp-01234");
    assert_eq!(logits_of(&lines[0]), mlp.forward(&x).unwrap().data, "v1 fallback diverged");
    assert_eq!(lines[1].get("model").unwrap().str().unwrap(), "convnet");
    assert_eq!(logits_of(&lines[1]), convnet.forward(&x).unwrap().data, "v2 routing diverged");
    assert_eq!(lines[2].get("code").unwrap().str().unwrap(), "unknown_model");
    assert!(lines[2].get("error").unwrap().str().unwrap().contains("ghost"));
    assert_eq!(lines[3].get("code").unwrap().str().unwrap(), "bad_request");
    assert!(lines[3].get("error").unwrap().str().unwrap().contains("requires protocol v2"));
    let models = lines[4].get("models").unwrap().arr().unwrap();
    assert_eq!(models.len(), 2);
    // sorted by name: convnet, then mlp — stats carry the full digest
    assert_eq!(models[0].get("model").unwrap().str().unwrap(), "convnet");
    assert_eq!(models[1].get("fp").unwrap().str().unwrap(), "fp-mlp-0123456789abcdef");
    server.shutdown();
}
