//! Serving-runtime integration tests: the acceptance bar is that a
//! request answered through the concurrent batched path carries
//! **bit-identical** logits to the same example scored by offline
//! `--exec int8` eval — micro-batching is a latency/throughput lever,
//! never an accuracy one.
//!
//! Also covered here: deadline flush with a partial batch, routing to
//! the correct submitter under concurrency, token-model validation at
//! submission, the f32 reference engine, drain-on-shutdown, and the
//! JSONL protocol end-to-end through `serve_stream`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use efqat::backend::native::model_graph;
use efqat::backend::Value;
use efqat::cfg::Config;
use efqat::coordinator::tasks::test_loader;
use efqat::coordinator::{evaluate_int8, example_inputs};
use efqat::json::Json;
use efqat::lower::{lower, QuantizedGraph};
use efqat::model::{ParamStore, QParamStore};
use efqat::serve::{BatchCfg, Engine, FloatEngine, Server, ServeCfg};
use efqat::tensor::{ITensor, Tensor};

/// The shared synthetic lowering fixture, pre-lowered: real weights from
/// the init distribution, mid-grid activation qparams.
fn fixture(model: &str) -> (QuantizedGraph, ParamStore, QParamStore) {
    let (g, params, q) = efqat::testing::synth_lowering_fixture(model);
    let qg = lower(&g, &params, &q, 8, 8).unwrap();
    (qg, params, q)
}

fn serve_cfg(max_batch: usize, wait: Duration, workers: usize) -> ServeCfg {
    ServeCfg { batch: BatchCfg { max_batch, max_wait: wait }, workers, queue_cap: 256 }
}

#[test]
fn batched_serving_is_bit_identical_to_int8_eval() {
    // the same loader drives offline eval and the request stream
    let (qg, _, _) = fixture("mlp");
    let cfg = Config::empty();
    let mut loader = test_loader("mlp", 32, &cfg).unwrap();
    let eval = evaluate_int8(&qg, &mut loader).unwrap();
    assert!(eval.n > 0);

    let engine = Arc::new(fixture("mlp").0);
    let server = Server::start(
        engine.clone() as Arc<dyn Engine>,
        serve_cfg(16, Duration::from_millis(1), 2),
    );
    let mut loader = test_loader("mlp", 32, &cfg).unwrap();
    loader.reset();
    let mut checked = 0usize;
    while let Some(batch) = loader.next_batch() {
        let examples = example_inputs(engine.input, &batch).unwrap();
        // single-request reference: a batch-of-1 forward per example
        let singles: Vec<Tensor> = examples
            .iter()
            .map(|v| {
                let one = match v {
                    Value::F32(t) => {
                        let mut shape = vec![1];
                        shape.extend_from_slice(&t.shape);
                        Value::F32(Tensor { shape, data: t.data.clone() })
                    }
                    Value::I32(t) => {
                        let mut shape = vec![1];
                        shape.extend_from_slice(&t.shape);
                        Value::I32(ITensor { shape, data: t.data.clone() })
                    }
                };
                engine.forward_owned(one).unwrap()
            })
            .collect();
        let tickets: Vec<_> = examples.into_iter().map(|v| server.submit(v).unwrap()).collect();
        for (t, want) in tickets.into_iter().zip(singles) {
            let got = t.wait().unwrap();
            assert_eq!(got.data, want.data, "batched logits diverged from batch-of-1");
            checked += 1;
        }
    }
    assert_eq!(checked, eval.n, "served exactly the examples eval scored");
    server.shutdown();
}

#[test]
fn worker_workspace_survives_batch_resizing_bit_identically() {
    // one worker, waves of different sizes: the worker's reused
    // workspace sees the dynamic batch grow, shrink, and regrow; every
    // answer must still be bit-identical to a fresh-allocation forward
    let engine = Arc::new(fixture("mlp").0);
    let server = Server::start(
        engine.clone() as Arc<dyn Engine>,
        serve_cfg(64, Duration::from_millis(1), 1),
    );
    let mut rng = efqat::rng::Pcg64::new(77);
    for (wave, &count) in [4usize, 17, 1, 9, 33, 2].iter().enumerate() {
        let examples: Vec<Tensor> = (0..count)
            .map(|_| Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) })
            .collect();
        let tickets: Vec<_> = examples
            .iter()
            .map(|x| server.submit(Value::F32(x.clone())).unwrap())
            .collect();
        for (x, t) in examples.iter().zip(tickets) {
            let got = t.wait().unwrap();
            let want = engine
                .forward(&Value::F32(Tensor { shape: vec![1, 3, 8, 8], data: x.data.clone() }))
                .unwrap();
            assert_eq!(got.data, want.data, "wave {wave} (count {count})");
        }
    }
    server.shutdown();
}

#[test]
fn concurrent_submitters_get_their_own_logits() {
    let engine = Arc::new(fixture("mlp").0);
    let server = Server::start(
        engine.clone() as Arc<dyn Engine>,
        serve_cfg(8, Duration::from_millis(1), 3),
    );
    std::thread::scope(|s| {
        for t in 0..6u64 {
            let (server, engine) = (&server, &engine);
            s.spawn(move || {
                let mut rng = efqat::rng::Pcg64::new(100 + t);
                for _ in 0..40 {
                    let x = Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) };
                    let want = engine
                        .forward(&Value::F32(Tensor {
                            shape: vec![1, 3, 8, 8],
                            data: x.data.clone(),
                        }))
                        .unwrap();
                    let got = server.submit(Value::F32(x)).unwrap().wait().unwrap();
                    // distinct random inputs per submitter: any misrouted
                    // response would fail this equality
                    assert_eq!(got.data, want.data, "response routed to the wrong request");
                }
            });
        }
    });
    server.shutdown();
}

#[test]
fn deadline_flushes_partial_batches() {
    let engine = Arc::new(fixture("mlp").0);
    // max_batch far above the offered load: only the deadline can flush
    let server = Server::start(
        engine as Arc<dyn Engine>,
        serve_cfg(1024, Duration::from_millis(10), 1),
    );
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..3)
        .map(|_| server.submit(Value::F32(Tensor::zeros(&[3, 8, 8]))).unwrap())
        .collect();
    for t in tickets {
        assert_eq!(t.wait().unwrap().shape, vec![10]);
    }
    let waited = t0.elapsed();
    assert!(waited >= Duration::from_millis(10), "flushed before the deadline: {waited:?}");
    assert!(waited < Duration::from_secs(10), "deadline flush did not engage");
    server.shutdown();
}

#[test]
fn token_model_serves_and_validates_ids() {
    let engine = Arc::new(fixture("tiny_tf").0);
    let server = Server::start(
        engine.clone() as Arc<dyn Engine>,
        serve_cfg(4, Duration::from_millis(1), 2),
    );
    let ids = ITensor { shape: vec![16], data: (0..16).map(|i| i % 64).collect() };
    let want = engine
        .forward(&Value::I32(ITensor { shape: vec![1, 16], data: ids.data.clone() }))
        .unwrap();
    let got = server.submit(Value::I32(ids)).unwrap().wait().unwrap();
    assert_eq!(got.shape, vec![16, 64]);
    assert_eq!(got.data, want.data);
    // an out-of-vocab id is rejected at submit — it never joins a batch
    let bad = ITensor { shape: vec![16], data: vec![99; 16] };
    let err = server.submit(Value::I32(bad)).unwrap_err().to_string();
    assert!(err.contains("out of range"), "{err}");
    server.shutdown();
}

#[test]
fn f32_engine_serves_within_fakequant_tolerance() {
    let (qg, params, q) = fixture("convnet");
    let engine = Arc::new(FloatEngine::new(
        model_graph("convnet").unwrap(),
        params,
        Some(q),
        8,
        8,
    ));
    let server = Server::start(
        engine as Arc<dyn Engine>,
        serve_cfg(4, Duration::from_millis(1), 1),
    );
    let mut rng = efqat::rng::Pcg64::new(5);
    // odd request count: exercises a partial trailing batch in f32 too
    let examples: Vec<Tensor> =
        (0..5).map(|_| Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) }).collect();
    let tickets: Vec<_> = examples
        .iter()
        .map(|x| server.submit(Value::F32(x.clone())).unwrap())
        .collect();
    for (x, t) in examples.iter().zip(tickets) {
        let got = t.wait().unwrap();
        let int8 = qg
            .forward(&Value::F32(Tensor { shape: vec![1, 3, 8, 8], data: x.data.clone() }))
            .unwrap();
        // f32 vs int8 agree to the lowering tolerance (int8_parity bar)
        for (a, b) in got.data.iter().zip(&int8.data) {
            assert!((a - b).abs() <= 1e-3 * a.abs().max(1.0), "{a} vs {b}");
        }
    }
    server.shutdown();
}

#[test]
fn jsonl_stream_round_trips_bit_identically() {
    let engine = Arc::new(fixture("mlp").0);
    let server = Server::start(
        engine.clone() as Arc<dyn Engine>,
        serve_cfg(8, Duration::from_millis(1), 2),
    );
    let mut rng = efqat::rng::Pcg64::new(11);
    let examples: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(192, 1.0)).collect();
    let mut input = String::new();
    for (i, ex) in examples.iter().enumerate() {
        let nums: Vec<String> = ex.iter().map(|v| format!("{}", *v as f64)).collect();
        input.push_str(&format!("{{\"id\": {i}, \"data\": [{}]}}\n", nums.join(",")));
    }
    input.push_str("{\"id\": \"bad\", \"data\": [1, 2]}\n"); // wrong length → error line

    let mut out: Vec<u8> = Vec::new();
    let n = efqat::serve::protocol::serve_stream(&server, input.as_bytes(), &mut out).unwrap();
    assert_eq!(n, 5);
    let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().trim().lines().collect();
    assert_eq!(lines.len(), 5);
    // FIFO responses: line i answers request i
    for (i, ex) in examples.iter().enumerate() {
        let doc = Json::parse(lines[i]).unwrap();
        assert_eq!(doc.get("id").unwrap().num().unwrap() as usize, i);
        let logits: Vec<f32> = doc
            .get("logits")
            .unwrap()
            .arr()
            .unwrap()
            .iter()
            .map(|j| j.num().unwrap() as f32)
            .collect();
        let want = engine
            .forward(&Value::F32(Tensor { shape: vec![1, 3, 8, 8], data: ex.clone() }))
            .unwrap();
        // f64 text round-trip is exact for f32 values
        assert_eq!(logits, want.data, "request {i}");
    }
    let err = Json::parse(lines[4]).unwrap();
    assert_eq!(err.get("id").unwrap().str().unwrap(), "bad");
    assert!(err.get("error").unwrap().str().unwrap().contains("2 elements"));
    server.shutdown();
}

#[test]
fn shutdown_answers_everything_accepted() {
    let engine = Arc::new(fixture("mlp").0);
    let server = Server::start(
        engine as Arc<dyn Engine>,
        // huge batch + long wait: shutdown itself must force the drain
        serve_cfg(512, Duration::from_secs(30), 2),
    );
    let tickets: Vec<_> = (0..40)
        .map(|i| {
            let mut rng = efqat::rng::Pcg64::new(i);
            let x = Tensor { shape: vec![3, 8, 8], data: rng.normal_vec(192, 1.0) };
            server.submit(Value::F32(x)).unwrap()
        })
        .collect();
    server.shutdown();
    for t in tickets {
        assert_eq!(t.wait().unwrap().shape, vec![10], "request dropped during shutdown");
    }
}
