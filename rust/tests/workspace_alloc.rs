//! Steady-state allocation-count tests for the planned executors
//! (RFC `docs/rfcs/0003-exec-plan.md`).
//!
//! A counting global allocator (thread-local counters, so parallel
//! tests cannot pollute each other) proves the headline claim of the
//! execution-plan refactor: after one warmup iteration over a
//! [`efqat::exec::Workspace`], the int8 serving forward and the native
//! train step (forward + frozen-channel-aware partial backward +
//! positional outputs) perform **zero** heap allocations per
//! request batch / per step.  The shapes used here stay below the GEMM
//! threading threshold, so no worker threads (whose stacks the OS
//! allocates) muddy the count — thread-level scratch is covered by the
//! `par_rows_scratch` plumbing and the workspace stats assertions.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::path::Path;

use efqat::backend::native::NativeBackend;
use efqat::backend::{Backend, Value};
use efqat::exec::Workspace;
use efqat::model::{Dtype, Manifest, ParamStore};
use efqat::rng::Pcg64;
use efqat::tensor::{ITensor, Tensor};

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// Valid inputs for any native manifest without a dataset: initialized
/// params, sane qparams, random images / zero token ids, first-k
/// selections (mirrors the integration-test helper).
fn generic_inputs(man: &Manifest, params: &ParamStore, seed: u64) -> Vec<Value> {
    let mut rng = Pcg64::new(seed);
    man.inputs
        .iter()
        .map(|spec| match spec.role.as_str() {
            "param" => Value::F32(params.get(&spec.name).unwrap().clone()),
            "qparam_sw" => {
                Value::F32(Tensor { shape: spec.shape.clone(), data: vec![0.05; spec.elems()] })
            }
            "qparam_sx" => Value::F32(Tensor::scalar(0.05)),
            "qparam_zx" => Value::F32(Tensor::scalar(128.0)),
            "data" => match spec.dtype {
                Dtype::F32 => Value::F32(Tensor {
                    shape: spec.shape.clone(),
                    data: rng.normal_vec(spec.elems(), 1.0),
                }),
                Dtype::I32 => Value::I32(ITensor::zeros(&spec.shape)),
            },
            "index" => Value::I32(ITensor {
                shape: spec.shape.clone(),
                data: (0..spec.shape[0] as i32).collect(),
            }),
            "flag" => Value::I32(ITensor { shape: vec![1], data: vec![1] }),
            other => panic!("unexpected input role {other:?}"),
        })
        .collect()
}

#[test]
fn int8_serve_forward_is_allocation_free_after_warmup() {
    // run the whole assertion once per dispatchable SIMD kernel: a
    // vector kernel that sneaks in a spill buffer fails here with the
    // kernel named, not just under the default dispatch
    for kidx in 0..efqat::ops::simd::kernels().len() {
        efqat::ops::simd::force(Some(kidx));
        let kname = efqat::ops::simd::active().name;
        for model in ["mlp", "tiny_tf"] {
            let (g, params, q) = efqat::testing::synth_lowering_fixture(model);
            let qg = efqat::lower::lower(&g, &params, &q, 8, 8).unwrap();
            let b = 4usize;
            let x = match g.input {
                efqat::graph::InputKind::Image { channels, hw } => {
                    let mut rng = Pcg64::new(3);
                    Value::F32(Tensor {
                        shape: vec![b, channels, hw, hw],
                        data: rng.normal_vec(b * channels * hw * hw, 1.0),
                    })
                }
                efqat::graph::InputKind::Tokens { seq } => Value::I32(ITensor {
                    shape: vec![b, seq],
                    data: (0..b * seq).map(|i| (i % 64) as i32).collect(),
                }),
            };
            let mut ws = Workspace::new();
            for _ in 0..3 {
                let y = qg.forward_into(&x, &mut ws).unwrap();
                ws.give_f32(y);
            }
            let allocs0 = thread_allocs();
            let misses0 = ws.stats().misses;
            for _ in 0..8 {
                let y = qg.forward_into(&x, &mut ws).unwrap();
                ws.give_f32(y);
            }
            let delta = thread_allocs() - allocs0;
            assert_eq!(
                delta, 0,
                "{model} [{kname}]: int8 forward allocated {delta}×/8 in steady state"
            );
            assert_eq!(
                ws.stats().misses, misses0,
                "{model} [{kname}]: workspace pool missed in steady state"
            );
        }
    }
    efqat::ops::simd::force(None);
}

#[test]
fn train_step_execution_is_allocation_free_after_warmup() {
    let backend = NativeBackend::new(Path::new("artifacts"));
    for artifact in
        ["mlp_w8a8_train_r25", "convnet_w8a8_train_r25", "tiny_tf_w8a8_train_r25", "mlp_fp_train"]
    {
        let step = backend.load(artifact).unwrap();
        let params = ParamStore::init(&step.manifest, 1);
        let inputs = generic_inputs(&step.manifest, &params, 7);
        let mut ws = Workspace::new();
        for _ in 0..3 {
            let (outs, _) = step.execute_timed_ws(&inputs, &mut ws).unwrap();
            ws.give_values(outs);
        }
        let allocs0 = thread_allocs();
        let misses0 = ws.stats().misses;
        for _ in 0..8 {
            let (outs, _) = step.execute_timed_ws(&inputs, &mut ws).unwrap();
            ws.give_values(outs);
        }
        let delta = thread_allocs() - allocs0;
        assert_eq!(delta, 0, "{artifact}: train step allocated {delta}×/8 in steady state");
        assert_eq!(ws.stats().misses, misses0, "{artifact}: pool missed in steady state");
    }
}
