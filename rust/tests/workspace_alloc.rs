//! Steady-state allocation-count tests for the planned executors
//! (RFC `docs/rfcs/0003-exec-plan.md`).
//!
//! A counting global allocator (thread-local counters, so parallel
//! tests cannot pollute each other) proves the headline claim of the
//! execution-plan refactor: after one warmup iteration over a
//! [`efqat::exec::Workspace`], the int8 serving forward and the native
//! train step (forward + frozen-channel-aware partial backward +
//! positional outputs) perform **zero** heap allocations per
//! request batch / per step.  The shapes used here stay below the GEMM
//! threading threshold, so no worker threads (whose stacks the OS
//! allocates) muddy the count — thread-level scratch is covered by the
//! `par_rows_scratch` plumbing and the workspace stats assertions.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::path::Path;

use efqat::backend::native::NativeBackend;
use efqat::backend::{Backend, Value};
use efqat::exec::Workspace;
use efqat::model::{Dtype, Manifest, ParamStore};
use efqat::rng::Pcg64;
use efqat::tensor::{ITensor, Tensor};

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// Valid inputs for any native manifest without a dataset: initialized
/// params, sane qparams, random images / zero token ids, first-k
/// selections (mirrors the integration-test helper).
fn generic_inputs(man: &Manifest, params: &ParamStore, seed: u64) -> Vec<Value> {
    let mut rng = Pcg64::new(seed);
    man.inputs
        .iter()
        .map(|spec| match spec.role.as_str() {
            "param" => Value::F32(params.get(&spec.name).unwrap().clone()),
            "qparam_sw" => {
                Value::F32(Tensor { shape: spec.shape.clone(), data: vec![0.05; spec.elems()] })
            }
            "qparam_sx" => Value::F32(Tensor::scalar(0.05)),
            "qparam_zx" => Value::F32(Tensor::scalar(128.0)),
            "data" => match spec.dtype {
                Dtype::F32 => Value::F32(Tensor {
                    shape: spec.shape.clone(),
                    data: rng.normal_vec(spec.elems(), 1.0),
                }),
                Dtype::I32 => Value::I32(ITensor::zeros(&spec.shape)),
            },
            "index" => Value::I32(ITensor {
                shape: spec.shape.clone(),
                data: (0..spec.shape[0] as i32).collect(),
            }),
            "flag" => Value::I32(ITensor { shape: vec![1], data: vec![1] }),
            other => panic!("unexpected input role {other:?}"),
        })
        .collect()
}

#[test]
fn int8_serve_forward_is_allocation_free_after_warmup() {
    // run the whole assertion once per dispatchable SIMD kernel: a
    // vector kernel that sneaks in a spill buffer fails here with the
    // kernel named, not just under the default dispatch
    for kidx in 0..efqat::ops::simd::kernels().len() {
        efqat::ops::simd::force(Some(kidx));
        let kname = efqat::ops::simd::active().name;
        for model in ["mlp", "tiny_tf"] {
            let (g, params, q) = efqat::testing::synth_lowering_fixture(model);
            let qg = efqat::lower::lower(&g, &params, &q, 8, 8).unwrap();
            let b = 4usize;
            let x = match g.input {
                efqat::graph::InputKind::Image { channels, hw } => {
                    let mut rng = Pcg64::new(3);
                    Value::F32(Tensor {
                        shape: vec![b, channels, hw, hw],
                        data: rng.normal_vec(b * channels * hw * hw, 1.0),
                    })
                }
                efqat::graph::InputKind::Tokens { seq } => Value::I32(ITensor {
                    shape: vec![b, seq],
                    data: (0..b * seq).map(|i| (i % 64) as i32).collect(),
                }),
            };
            let mut ws = Workspace::new();
            for _ in 0..3 {
                let y = qg.forward_into(&x, &mut ws).unwrap();
                ws.give_f32(y);
            }
            let allocs0 = thread_allocs();
            let misses0 = ws.stats().misses;
            for _ in 0..8 {
                let y = qg.forward_into(&x, &mut ws).unwrap();
                ws.give_f32(y);
            }
            let delta = thread_allocs() - allocs0;
            assert_eq!(
                delta, 0,
                "{model} [{kname}]: int8 forward allocated {delta}×/8 in steady state"
            );
            assert_eq!(
                ws.stats().misses, misses0,
                "{model} [{kname}]: workspace pool missed in steady state"
            );
        }
    }
    efqat::ops::simd::force(None);
}

/// Drive the exact serve hot path (`worker::process_batch`) over
/// pre-built micro-batches and return the allocation count of the
/// steady-state window (3 warmup batches, 8 measured).
fn serve_batch_alloc_delta(trace: &efqat::serve::LaneTrace) -> u64 {
    use efqat::serve::queue::oneshot;
    use efqat::serve::{worker, EngineSlot, Request, Span};

    let (g, params, q) = efqat::testing::synth_lowering_fixture("mlp");
    let qg = efqat::lower::lower(&g, &params, &q, 8, 8).unwrap();
    let slot = std::sync::Mutex::new(EngineSlot {
        engine: std::sync::Arc::new(qg),
        model: std::sync::Arc::from("mlp"),
        fingerprint: std::sync::Arc::from("fp-mlp"),
        generation: 1,
    });
    let mut rng = Pcg64::new(9);
    // every batch (payloads, oneshots, spans) is built *outside* the
    // measured region — the measured allocations are the serve path's own
    let mut batches: Vec<Vec<Request>> = (0..11)
        .map(|_| {
            (0..4)
                .map(|_| {
                    let (tx, rx) = oneshot();
                    drop(rx); // replies are routed, not awaited, here
                    let input = Value::F32(Tensor {
                        shape: vec![3, 8, 8],
                        data: rng.normal_vec(192, 1.0),
                    });
                    Request { input, tx, span: Span::begin() }
                })
                .collect()
        })
        .collect();
    let mut ws = Workspace::new();
    let measured = batches.split_off(3);
    for batch in batches {
        worker::process_batch(&slot, batch, &mut ws, trace);
    }
    let allocs0 = thread_allocs();
    for batch in measured {
        worker::process_batch(&slot, batch, &mut ws, trace);
    }
    thread_allocs() - allocs0
}

#[test]
fn serve_batch_tracing_allocates_only_at_flush_boundaries() {
    use efqat::serve::{JsonlTraceRecorder, LaneTrace, TraceSubscriber};
    use std::sync::{Arc, Mutex};
    use std::time::Instant;

    struct SharedBuf(Arc<Mutex<Vec<u8>>>);
    impl std::io::Write for SharedBuf {
        fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(b);
            Ok(b.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    // A/B under the counting allocator: the baseline is the serve path
    // with tracing disabled; the live side runs the full pipeline — span
    // stamps, rolling histograms, EWMA, and a JSONL subscriber whose
    // buffer (cap 4096) cannot fill inside the window.  Tracing must add
    // exactly zero steady-state allocations.
    let baseline = serve_batch_alloc_delta(&LaneTrace::disabled(Arc::from("mlp")));
    let sink = Arc::new(Mutex::new(Vec::new()));
    let recorder = Arc::new(JsonlTraceRecorder::to_writer(
        Box::new(SharedBuf(sink.clone())),
        4096,
    ));
    let subs: Vec<Arc<dyn TraceSubscriber>> = vec![recorder.clone()];
    let live = LaneTrace::new(Arc::from("mlp"), Instant::now(), subs);
    let traced = serve_batch_alloc_delta(&live);
    assert_eq!(
        traced, baseline,
        "tracing allocated {traced} vs {baseline} per 8 steady-state batches"
    );
    // nothing was formatted or written inside the steady-state window ...
    assert!(sink.lock().unwrap().is_empty(), "subscriber wrote before a flush boundary");
    // ... and the explicit flush boundary emits every buffered event
    recorder.flush();
    let text = String::from_utf8(sink.lock().unwrap().clone()).unwrap();
    assert_eq!(text.lines().count(), 44, "11 batches of 4 requests each");
    assert!(text.lines().all(|l| l.contains("\"model\":\"mlp\"")), "wrong lane in trace");
}

#[test]
fn train_step_execution_is_allocation_free_after_warmup() {
    // once per registered f32 GEMM kernel: the training forward and
    // backward contractions all dispatch through the f32 table, so a
    // vector kernel that allocates scratch fails here with its name
    let backend = NativeBackend::new(Path::new("artifacts"));
    for kidx in 0..efqat::ops::simd::kernels_f32().len() {
        efqat::ops::simd::force_f32(Some(kidx));
        let kname = efqat::ops::simd::active_f32().name;
        for artifact in [
            "mlp_w8a8_train_r25",
            "convnet_w8a8_train_r25",
            "tiny_tf_w8a8_train_r25",
            "mlp_fp_train",
        ] {
            let step = backend.load(artifact).unwrap();
            let params = ParamStore::init(&step.manifest, 1);
            let inputs = generic_inputs(&step.manifest, &params, 7);
            let mut ws = Workspace::new();
            for _ in 0..3 {
                let (outs, _) = step.execute_timed_ws(&inputs, &mut ws).unwrap();
                ws.give_values(outs);
            }
            let allocs0 = thread_allocs();
            let misses0 = ws.stats().misses;
            for _ in 0..8 {
                let (outs, _) = step.execute_timed_ws(&inputs, &mut ws).unwrap();
                ws.give_values(outs);
            }
            let delta = thread_allocs() - allocs0;
            assert_eq!(
                delta, 0,
                "{artifact} [{kname}]: train step allocated {delta}×/8 in steady state"
            );
            assert_eq!(
                ws.stats().misses, misses0,
                "{artifact} [{kname}]: pool missed in steady state"
            );
        }
    }
    efqat::ops::simd::force_f32(None);
}

#[test]
fn truncated_train_step_is_allocation_free_after_warmup() {
    // frozen-prefix backward truncation swaps real layer backwards for
    // the skip path (cache recycling + zero-grad emission from the
    // workspace pool) — that path must be exactly as allocation-free as
    // the full backward it replaces
    let backend = NativeBackend::new(Path::new("artifacts"));
    for (artifact, n_frozen) in
        [("mlp_w8a8_train_lwpn", 1usize), ("tiny_tf_w8a8_train_lwpn", 4)]
    {
        let step = backend.load(artifact).unwrap();
        let params = ParamStore::init(&step.manifest, 1);
        let frozen: Vec<String> =
            step.manifest.wsites.iter().take(n_frozen).map(|w| w.name.clone()).collect();
        let inputs: Vec<Value> = step
            .manifest
            .inputs
            .iter()
            .zip(generic_inputs(&step.manifest, &params, 7))
            .map(|(spec, v)| {
                if spec.role == "flag" && frozen.contains(spec.of.as_ref().unwrap()) {
                    Value::I32(ITensor { shape: vec![1], data: vec![0] })
                } else {
                    v
                }
            })
            .collect();
        efqat::graph::force_backward_truncation(Some(true));
        let mut ws = Workspace::new();
        for _ in 0..3 {
            let (outs, _) = step.execute_timed_ws(&inputs, &mut ws).unwrap();
            ws.give_values(outs);
        }
        let allocs0 = thread_allocs();
        let misses0 = ws.stats().misses;
        for _ in 0..8 {
            let (outs, _) = step.execute_timed_ws(&inputs, &mut ws).unwrap();
            ws.give_values(outs);
        }
        let delta = thread_allocs() - allocs0;
        efqat::graph::force_backward_truncation(None);
        assert_eq!(delta, 0, "{artifact}: truncated step allocated {delta}×/8 in steady state");
        assert_eq!(ws.stats().misses, misses0, "{artifact}: pool missed in steady state");
    }
}
