//! Integration tests: rust coordinator × real AOT artifacts.
//!
//! These exercise the full cross-language ABI — manifest binding, PJRT
//! execution, PTQ calibration, EfQAT steps with channel/layer freezing —
//! against the resnet8 artifacts.  They require `make artifacts` to have
//! run; if the artifacts are missing the tests fail with a clear message.

use std::path::{Path, PathBuf};
use std::rc::Rc;

use efqat::cfg::Config;
use efqat::coordinator::binder::{bind_inputs, BindCtx};
use efqat::coordinator::tasks::build_task;
use efqat::coordinator::trainer::{pretrain_fp, EfqatTrainer, TrainCfg};
use efqat::coordinator::{calibrate, evaluate, Session};
use efqat::freeze::Mode;
use efqat::model::{ParamStore, StateStore};

fn artifacts_dir() -> PathBuf {
    let candidates = ["artifacts", "../artifacts"];
    for c in candidates {
        if Path::new(c).join("resnet8_fp_train.hlo.txt").exists() {
            return PathBuf::from(c);
        }
    }
    panic!("artifacts not found — run `make artifacts` first");
}

fn small_cfg() -> Config {
    let mut cfg = Config::empty();
    cfg.set("data.train_n", "256");
    cfg.set("data.test_n", "128");
    cfg.set("data.calib_samples", "128");
    cfg
}

fn session() -> Session {
    Session::new(&artifacts_dir()).expect("PJRT session")
}

#[test]
fn fwd_artifact_executes_and_scores() {
    let s = session();
    let fwd = s.steps.get("resnet8_fp_fwd").unwrap();
    let params = ParamStore::init(&fwd.manifest, 0);
    let states = StateStore::init(&fwd.manifest);
    let mut task = build_task("resnet8", fwd.manifest.batch_size, &small_cfg()).unwrap();
    let r = evaluate(&fwd, &params, None, &states, &mut task.test).unwrap();
    assert!(r.loss.is_finite());
    assert_eq!(r.n, 128);
    // untrained net ≈ chance
    assert!(r.accuracy < 0.5);
}

#[test]
fn fp_pretraining_reduces_loss() {
    let s = session();
    let step = s.steps.get("resnet8_fp_train").unwrap();
    let mut params = ParamStore::init(&step.manifest, 0);
    let mut states = StateStore::init(&step.manifest);
    let mut task = build_task("resnet8", step.manifest.batch_size, &small_cfg()).unwrap();
    let cfg = TrainCfg { lr_w: 0.05, ..TrainCfg::default() };
    let log = pretrain_fp(&step, &mut params, &mut states, &mut task.train, 3, &cfg).unwrap();
    let first = log.records[0].loss;
    let last = log.mean_loss_tail(4);
    assert!(last < first * 0.9, "loss did not drop: {first} -> {last}");
}

#[test]
fn calibration_produces_sane_qparams() {
    let s = session();
    let calib = s.steps.get("resnet8_calib").unwrap();
    let params = ParamStore::init(&calib.manifest, 0);
    let states = StateStore::init(&calib.manifest);
    let mut task = build_task("resnet8", calib.manifest.batch_size, &small_cfg()).unwrap();
    let q = calibrate(&calib, &params, &states, &mut task.calib, 128, 8, 8).unwrap();
    assert_eq!(q.sw.len(), calib.manifest.wsites.len());
    assert_eq!(q.act.len(), calib.manifest.wsites.len());
    for (site, act) in &q.act {
        assert!(act.scale > 0.0, "{site}: scale {}", act.scale);
        assert!(act.zero_point >= 0.0 && act.zero_point <= 255.0, "{site}");
    }
    // the first conv sees raw data (std ~1, range ~±4) → scale ~ 8/255
    let stem = &q.act["stem.conv"];
    assert!(stem.scale > 0.005 && stem.scale < 0.2, "stem scale {}", stem.scale);
}

fn make_trainer(s: &Session, artifact: &str, mode: Option<Mode>) -> (EfqatTrainer, efqat::coordinator::tasks::Task) {
    let calib = s.steps.get("resnet8_calib").unwrap();
    let params = ParamStore::init(&calib.manifest, 0);
    let states = StateStore::init(&calib.manifest);
    let mut task = build_task("resnet8", calib.manifest.batch_size, &small_cfg()).unwrap();
    let q = calibrate(&calib, &params, &states, &mut task.calib, 128, 8, 8).unwrap();
    let step = s.steps.get(artifact).unwrap();
    let tcfg = TrainCfg { lr_w: 0.05, ..TrainCfg::default() };
    let trainer = EfqatTrainer::new(step, params, q, states, mode, tcfg).unwrap();
    (trainer, task)
}

#[test]
fn efqat_ratio_step_updates_only_selected_rows() {
    let s = session();
    let (mut trainer, mut task) = make_trainer(&s, "resnet8_w8a8_train_r25", Some(Mode::Cwpl));
    let before = trainer.params.get("s1.b0.c1").unwrap().clone();
    let sel = trainer.policy.as_ref().unwrap().selection().clone();
    let si = trainer
        .step
        .manifest
        .wsites
        .iter()
        .position(|w| w.name == "s1.b0.c1")
        .unwrap();
    let selected = sel.channels[si].clone();
    assert!(!selected.is_empty());

    task.train.reset();
    let batch = task.train.next_batch().unwrap();
    let rec = trainer.train_step(&batch).unwrap();
    assert!(rec.loss.is_finite());

    let after = trainer.params.get("s1.b0.c1").unwrap();
    let rows = before.rows();
    for r in 0..rows {
        let changed = before.row(r) != after.row(r);
        assert_eq!(
            changed,
            selected.contains(&r),
            "row {r}: changed={changed}, selected={}",
            selected.contains(&r)
        );
    }
    // sw likewise: only selected rows move
    let sw = &trainer.qparams.sw["s1.b0.c1"];
    assert_eq!(sw.shape[0], rows);
}

#[test]
fn efqat_lwpn_step_skips_frozen_layers() {
    let s = session();
    let (mut trainer, mut task) = make_trainer(&s, "resnet8_w8a8_train_lwpn", Some(Mode::Lwpn));
    // force ratio-driven flags: policy built with artifact ratio (1.0 for the
    // lwpn artifact); rebuild with a tighter budget through cfg is indirect,
    // so instead check consistency: frozen ⇔ unchanged
    let flags = trainer.policy.as_ref().unwrap().selection().flags.clone();
    let names: Vec<String> = trainer.step.manifest.wsites.iter().map(|w| w.name.clone()).collect();
    let before: Vec<_> = names.iter().map(|n| trainer.params.get(n).unwrap().clone()).collect();

    task.train.reset();
    let batch = task.train.next_batch().unwrap();
    trainer.train_step(&batch).unwrap();

    for ((name, before), &flag) in names.iter().zip(&before).zip(&flags) {
        let after = trainer.params.get(name).unwrap();
        let changed = before.data != after.data;
        assert_eq!(changed, flag, "{name}: changed={changed} flag={flag}");
    }
}

#[test]
fn efqat_epoch_improves_over_ptq() {
    let s = session();
    let (mut trainer, mut task) = make_trainer(&s, "resnet8_w8a8_train_r50", Some(Mode::Cwpn));
    // quantized eval before
    let fwd = s.steps.get("resnet8_w8a8_fwd").unwrap();
    let before = evaluate(&fwd, &trainer.params, Some(&trainer.qparams), &trainer.states, &mut task.test).unwrap();
    let log = trainer.train_epoch(&mut task.train).unwrap();
    let after = evaluate(&fwd, &trainer.params, Some(&trainer.qparams), &trainer.states, &mut task.test).unwrap();
    // untrained random net + an 8-batch epoch: require genuine progress but
    // leave room for SGD noise at this tiny scale
    assert!(
        log.mean_loss_tail(4) < log.records[0].loss * 1.1,
        "no training progress: {} -> {}",
        log.records[0].loss,
        log.mean_loss_tail(4)
    );
    assert!(after.loss <= before.loss * 1.25, "eval loss regressed: {} -> {}", before.loss, after.loss);
}

#[test]
fn binder_rejects_wrong_selection_size() {
    let s = session();
    let step = s.steps.get("resnet8_w8a8_train_r25").unwrap();
    let params = ParamStore::init(&step.manifest, 0);
    let states = StateStore::init(&step.manifest);
    let mut task = build_task("resnet8", step.manifest.batch_size, &small_cfg()).unwrap();
    let batch = task.train.next_batch().unwrap();
    // selection with wrong channel counts must be rejected at bind time
    let bad = efqat::freeze::Selection {
        channels: vec![vec![0]; step.manifest.wsites.len()],
        flags: vec![true; step.manifest.wsites.len()],
    };
    let mut q = efqat::model::QParamStore::default();
    q.init_weight_scales(&step.manifest, &params, 8);
    for w in &step.manifest.wsites {
        q.act.insert(w.name.clone(), efqat::quant::ActQParams { scale: 0.05, zero_point: 0.0 });
    }
    let ctx = BindCtx { params: &params, qparams: Some(&q), states: &states, batch: &batch, selection: Some(&bad) };
    let err = bind_inputs(&step.manifest, &ctx);
    assert!(err.is_err());
}

#[test]
fn qat_and_ratio_artifacts_agree_on_loss() {
    // identical params/batch → identical forward loss regardless of ratio
    let s = session();
    let (mut t1, mut task) = make_trainer(&s, "resnet8_w8a8_train_r100", None);
    let (mut t2, _) = make_trainer(&s, "resnet8_w8a8_train_r25", Some(Mode::Cwpl));
    task.train.reset();
    let batch = task.train.next_batch().unwrap();
    let r1 = t1.train_step(&batch).unwrap();
    let r2 = t2.train_step(&batch).unwrap();
    assert!(
        (r1.loss - r2.loss).abs() < 1e-4,
        "loss mismatch: qat {} vs r25 {}",
        r1.loss,
        r2.loss
    );
}
