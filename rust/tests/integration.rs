//! Integration tests: rust coordinator × the native CPU reference backend.
//!
//! These exercise the full execution ABI — manifest binding, step
//! execution, PTQ calibration, EfQAT steps with channel/layer freezing —
//! against the native `mlp` model, so `cargo test` needs no Python-built
//! artifacts and no PJRT runtime.  The same tests run unchanged against
//! the PJRT backend by swapping the [`Session`] constructor.

use std::path::Path;

use efqat::backend::{BackendKind, Value};
use efqat::cfg::Config;
use efqat::coordinator::binder::{bind_inputs, BindCtx};
use efqat::coordinator::tasks::build_task;
use efqat::coordinator::trainer::{pretrain_fp, EfqatTrainer, TrainCfg};
use efqat::coordinator::{calibrate, evaluate, Session};
use efqat::freeze::Mode;
use efqat::model::{Dtype, Manifest, ParamStore, StateStore};
use efqat::quant::{fq_asym, fq_sym};
use efqat::rng::Pcg64;
use efqat::tensor::{ITensor, Tensor};

fn session() -> Session {
    Session::new(Path::new("artifacts")).expect("native session")
}

fn small_cfg() -> Config {
    let mut cfg = Config::empty();
    cfg.set("data.train_n", "256");
    cfg.set("data.test_n", "128");
    cfg.set("data.calib_samples", "128");
    cfg
}

#[test]
fn backend_selection_is_explicit_and_fails_loudly() {
    // native by name
    assert!(Session::with_backend(BackendKind::Native, Path::new("artifacts")).is_ok());
    // unknown backend names are rejected with the available set
    let err = BackendKind::parse("tpu").unwrap_err().to_string();
    assert!(err.contains("native"), "{err}");
    // without the pjrt feature, asking for pjrt is a descriptive error,
    // not a panic (with the feature it fails on the missing bundle)
    let mut cfg = small_cfg();
    cfg.set("backend", "pjrt");
    cfg.set("artifacts", "/definitely/not/artifacts");
    let err = match Session::from_cfg(&cfg) {
        Ok(_) => panic!("pjrt session from a nonexistent dir should fail"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("pjrt") || err.contains("manifest"), "{err}");
}

#[test]
fn unknown_model_yields_descriptive_error() {
    let s = session();
    let err = s.steps.get("resnet8_fp_train").unwrap_err().to_string();
    assert!(err.contains("no native reference implementation"), "{err}");
    assert!(err.contains("pjrt"), "{err}");
}

#[test]
fn fwd_artifact_executes_and_scores() {
    let s = session();
    let fwd = s.steps.get("mlp_fp_fwd").unwrap();
    let params = ParamStore::init(&fwd.manifest, 0);
    let states = StateStore::init(&fwd.manifest);
    let mut task = build_task("mlp", fwd.manifest.batch_size, &small_cfg()).unwrap();
    let r = evaluate(&fwd, &params, None, &states, &mut task.test).unwrap();
    assert!(r.loss.is_finite());
    assert_eq!(r.n, 128);
    // untrained net ≈ chance
    assert!(r.accuracy < 0.5);
}

#[test]
fn fp_pretraining_reduces_loss() {
    let s = session();
    let step = s.steps.get("mlp_fp_train").unwrap();
    let mut params = ParamStore::init(&step.manifest, 0);
    let mut states = StateStore::init(&step.manifest);
    let mut task = build_task("mlp", step.manifest.batch_size, &small_cfg()).unwrap();
    let cfg = TrainCfg { lr_w: 0.02, ..TrainCfg::default() };
    let log = pretrain_fp(&step, &mut params, &mut states, &mut task.train, 3, &cfg).unwrap();
    let first = log.records[0].loss;
    let last = log.mean_loss_tail(4);
    assert!(last < first * 0.9, "loss did not drop: {first} -> {last}");
}

#[test]
fn calibration_produces_sane_qparams() {
    let s = session();
    let calib = s.steps.get("mlp_calib").unwrap();
    let params = ParamStore::init(&calib.manifest, 0);
    let states = StateStore::init(&calib.manifest);
    let mut task = build_task("mlp", calib.manifest.batch_size, &small_cfg()).unwrap();
    let q = calibrate(&calib, &params, &states, &mut task.calib, 128, 8, 8).unwrap();
    assert_eq!(q.sw.len(), calib.manifest.wsites.len());
    assert_eq!(q.act.len(), calib.manifest.wsites.len());
    for (site, act) in &q.act {
        assert!(act.scale > 0.0, "{site}: scale {}", act.scale);
        assert!(act.zero_point >= 0.0 && act.zero_point <= 255.0, "{site}");
    }
    // the first layer sees raw data (std ~2, range ~±8) → scale well
    // inside (0.005, 0.2)
    let stem = &q.act["fc1.w"];
    assert!(stem.scale > 0.005 && stem.scale < 0.2, "stem scale {}", stem.scale);
}

fn make_trainer(
    s: &Session,
    artifact: &str,
    mode: Option<Mode>,
) -> (EfqatTrainer, efqat::coordinator::tasks::Task) {
    let calib = s.steps.get("mlp_calib").unwrap();
    let params = ParamStore::init(&calib.manifest, 0);
    let states = StateStore::init(&calib.manifest);
    let mut task = build_task("mlp", calib.manifest.batch_size, &small_cfg()).unwrap();
    let q = calibrate(&calib, &params, &states, &mut task.calib, 128, 8, 8).unwrap();
    let step = s.steps.get(artifact).unwrap();
    let tcfg = TrainCfg { lr_w: 0.02, ..TrainCfg::default() };
    let trainer = EfqatTrainer::new(step, params, q, states, mode, tcfg).unwrap();
    (trainer, task)
}

#[test]
fn efqat_ratio_step_updates_only_selected_rows() {
    let s = session();
    let (mut trainer, mut task) = make_trainer(&s, "mlp_w8a8_train_r25", Some(Mode::Cwpl));
    let before = trainer.params.get("fc1.w").unwrap().clone();
    let sel = trainer.policy.as_ref().unwrap().selection().clone();
    let si = trainer
        .step
        .manifest
        .wsites
        .iter()
        .position(|w| w.name == "fc1.w")
        .unwrap();
    let selected = sel.channels[si].clone();
    assert!(!selected.is_empty());

    task.train.reset();
    let batch = task.train.next_batch().unwrap();
    let rec = trainer.train_step(&batch).unwrap();
    assert!(rec.loss.is_finite());

    let after = trainer.params.get("fc1.w").unwrap();
    for r in 0..before.rows() {
        let changed = before.row(r) != after.row(r);
        assert_eq!(
            changed,
            selected.contains(&r),
            "row {r}: changed={changed}, selected={}",
            selected.contains(&r)
        );
    }
    // sw likewise: frozen rows keep their calibration value
    let sw = &trainer.qparams.sw["fc1.w"];
    assert_eq!(sw.shape[0], before.rows());
}

#[test]
fn efqat_lwpn_step_skips_frozen_layers() {
    let s = session();
    let (mut trainer, mut task) = make_trainer(&s, "mlp_w8a8_train_lwpn", Some(Mode::Lwpn));
    let flags = trainer.policy.as_ref().unwrap().selection().flags.clone();
    let names: Vec<String> =
        trainer.step.manifest.wsites.iter().map(|w| w.name.clone()).collect();
    let before: Vec<_> = names.iter().map(|n| trainer.params.get(n).unwrap().clone()).collect();

    task.train.reset();
    let batch = task.train.next_batch().unwrap();
    trainer.train_step(&batch).unwrap();

    for ((name, before), &flag) in names.iter().zip(&before).zip(&flags) {
        let after = trainer.params.get(name).unwrap();
        let changed = before.data != after.data;
        assert_eq!(changed, flag, "{name}: changed={changed} flag={flag}");
    }
}

#[test]
fn efqat_epoch_improves_over_ptq() {
    let s = session();
    let (mut trainer, mut task) = make_trainer(&s, "mlp_w8a8_train_r50", Some(Mode::Cwpn));
    let fwd = s.steps.get("mlp_w8a8_fwd").unwrap();
    let before =
        evaluate(&fwd, &trainer.params, Some(&trainer.qparams), &trainer.states, &mut task.test)
            .unwrap();
    let log = trainer.train_epoch(&mut task.train).unwrap();
    let after =
        evaluate(&fwd, &trainer.params, Some(&trainer.qparams), &trainer.states, &mut task.test)
            .unwrap();
    // untrained random net + a 16-batch epoch: require genuine progress
    // but leave room for SGD noise at this tiny scale
    assert!(
        log.mean_loss_tail(4) < log.records[0].loss * 1.1,
        "no training progress: {} -> {}",
        log.records[0].loss,
        log.mean_loss_tail(4)
    );
    assert!(
        after.loss <= before.loss * 1.25,
        "eval loss regressed: {} -> {}",
        before.loss,
        after.loss
    );
}

#[test]
fn binder_rejects_wrong_selection_size() {
    let s = session();
    let step = s.steps.get("mlp_w8a8_train_r25").unwrap();
    let params = ParamStore::init(&step.manifest, 0);
    let states = StateStore::init(&step.manifest);
    let mut task = build_task("mlp", step.manifest.batch_size, &small_cfg()).unwrap();
    let batch = task.train.next_batch().unwrap();
    // a selection with wrong channel counts must be rejected at bind time
    let bad = efqat::freeze::Selection {
        channels: vec![vec![0]; step.manifest.wsites.len()],
        flags: vec![true; step.manifest.wsites.len()],
    };
    let mut q = efqat::model::QParamStore::default();
    q.init_weight_scales(&step.manifest, &params, 8);
    for w in &step.manifest.wsites {
        q.act.insert(w.name.clone(), efqat::quant::ActQParams { scale: 0.05, zero_point: 0.0 });
    }
    let ctx = BindCtx {
        params: &params,
        qparams: Some(&q),
        states: &states,
        batch: &batch,
        selection: Some(&bad),
    };
    let err = bind_inputs(&step.manifest, &ctx);
    assert!(err.is_err());
}

#[test]
fn step_rejects_wrong_batch_geometry() {
    // data generated at the wrong image size must fail at the ABI check
    // with a descriptive error, not garbage math
    let s = session();
    let fwd = s.steps.get("mlp_fp_fwd").unwrap();
    let params = ParamStore::init(&fwd.manifest, 0);
    let states = StateStore::init(&fwd.manifest);
    let mut cfg = small_cfg();
    cfg.set("data.hw", "16"); // native mlp manifests are built for 8×8
    let mut task = build_task("mlp", fwd.manifest.batch_size, &cfg).unwrap();
    let err = evaluate(&fwd, &params, None, &states, &mut task.test)
        .unwrap_err()
        .to_string();
    assert!(err.contains("manifest declares"), "{err}");
}

#[test]
fn qat_and_ratio_artifacts_agree_on_loss() {
    // identical params/batch → identical forward loss regardless of ratio
    let s = session();
    let (mut t1, mut task) = make_trainer(&s, "mlp_w8a8_train_r100", None);
    let (mut t2, _) = make_trainer(&s, "mlp_w8a8_train_r25", Some(Mode::Cwpl));
    task.train.reset();
    let batch = task.train.next_batch().unwrap();
    let r1 = t1.train_step(&batch).unwrap();
    let r2 = t2.train_step(&batch).unwrap();
    assert!(
        (r1.loss - r2.loss).abs() < 1e-5,
        "loss mismatch: qat {} vs r25 {}",
        r1.loss,
        r2.loss
    );
}

#[test]
fn r0_trains_qparams_but_never_weights() {
    let s = session();
    let (mut trainer, mut task) = make_trainer(&s, "mlp_w8a8_train_r0", None);
    let w_before = trainer.params.get("fc1.w").unwrap().clone();
    let sw_before = trainer.qparams.sw["fc1.w"].clone();
    let sx_before = trainer.qparams.act["fc1.w"].scale;
    task.train.reset();
    let batch = task.train.next_batch().unwrap();
    trainer.train_step(&batch).unwrap();
    assert_eq!(w_before.data, trainer.params.get("fc1.w").unwrap().data);
    assert_eq!(sw_before.data, trainer.qparams.sw["fc1.w"].data);
    // activation qparams still move (paper: qparams always train)
    assert_ne!(sx_before, trainer.qparams.act["fc1.w"].scale);
}

#[test]
fn native_fwd_matches_host_quant_math() {
    // Eq. 1–4 agreement: quantize a weight row + one activation with the
    // host-side quant.rs formulas, and check that feeding the same
    // parameters through the native fwd artifact produces logits built
    // from exactly those dequantized values.  One 1×1-ish configuration
    // makes the expected value analytic.
    let s = session();
    let fwd = s.steps.get("mlp_w8a8_fwd").unwrap();
    let man = &fwd.manifest;
    let mut params = ParamStore::init(man, 0);
    // zero everything, then set a single known path through the net
    for t in params.map.values_mut() {
        for v in t.data.iter_mut() {
            *v = 0.0;
        }
    }
    params.map.get_mut("fc1.w").unwrap().data[0] = 0.37; // row 0 reads x[0]
    params.map.get_mut("fc2.w").unwrap().data[0] = 0.91; // class 0 reads h[0]
    let mut q = efqat::model::QParamStore::default();
    q.init_weight_scales(man, &params, man.w_bits);
    q.act.insert("fc1.w".into(), efqat::quant::ActQParams { scale: 0.05, zero_point: 128.0 });
    q.act.insert("fc2.w".into(), efqat::quant::ActQParams { scale: 0.02, zero_point: 0.0 });

    // one batch with a known x[0]
    let b = man.batch_size;
    let d_in = 3 * 8 * 8;
    let mut x = Tensor::zeros(&[b, 3, 8, 8]);
    x.data[0] = 1.234;
    let states = StateStore::init(man);
    let batch = efqat::data::Batch {
        f32s: [("x".to_string(), x)].into_iter().collect(),
        i32s: [("y".to_string(), efqat::tensor::ITensor::zeros(&[b]))].into_iter().collect(),
        count: b,
    };
    let ctx = BindCtx {
        params: &params,
        qparams: Some(&q),
        states: &states,
        batch: &batch,
        selection: None,
    };
    let out = fwd.execute(&bind_inputs(man, &ctx).unwrap()).unwrap();
    let logits = out.get("logits").unwrap().f32().unwrap();

    // host-side expectation via quant.rs (Eq. 1–4)
    let sw1 = q.sw["fc1.w"].data[0];
    let sw2 = q.sw["fc2.w"].data[0];
    let xh = fq_asym(1.234, 0.05, 128.0, 8);
    let wh1 = fq_sym(0.37, sw1, 8);
    let h = (xh * wh1).max(0.0);
    let hh = fq_asym(h, 0.02, 0.0, 8);
    let wh2 = fq_sym(0.91, sw2, 8);
    let want = hh * wh2;
    assert!(
        (logits.data[0] - want).abs() < 1e-5,
        "native {} vs host {}",
        logits.data[0],
        want
    );
    // rows that read only zero inputs produce exactly zero (zero maps to
    // an exact code in both quantizers)
    assert!(logits.data[1].abs() < 1e-6);
    let _ = d_in;
}

/// Build valid inputs for any native manifest without a dataset: real
/// initialized params, sane qparams, random images / zero token ids, and
/// the first-k selection per site.
fn generic_inputs(man: &Manifest, params: &ParamStore, seed: u64) -> Vec<Value> {
    let mut rng = Pcg64::new(seed);
    man.inputs
        .iter()
        .map(|spec| match spec.role.as_str() {
            "param" => Value::F32(params.get(&spec.name).unwrap().clone()),
            "qparam_sw" => {
                Value::F32(Tensor { shape: spec.shape.clone(), data: vec![0.05; spec.elems()] })
            }
            "qparam_sx" => Value::F32(Tensor::scalar(0.05)),
            "qparam_zx" => Value::F32(Tensor::scalar(128.0)),
            "data" => match spec.dtype {
                Dtype::F32 => Value::F32(Tensor {
                    shape: spec.shape.clone(),
                    data: rng.normal_vec(spec.elems(), 1.0),
                }),
                // zeros are valid labels and valid token ids everywhere
                Dtype::I32 => Value::I32(ITensor::zeros(&spec.shape)),
            },
            "index" => Value::I32(ITensor {
                shape: spec.shape.clone(),
                data: (0..spec.shape[0] as i32).collect(),
            }),
            "flag" => Value::I32(ITensor { shape: vec![1], data: vec![1] }),
            other => panic!("unexpected input role {other:?}"),
        })
        .collect()
}

#[test]
fn every_native_model_executes_every_artifact_kind() {
    // the whole (model × step-kind) matrix runs through the graph
    // executor; Step::execute validates every output against the
    // manifest ABI in both directions, so this catches any shape drift
    let s = session();
    for model in ["mlp", "mlp_wide", "convnet", "tiny_tf"] {
        for suffix in [
            "calib",
            "fp_train",
            "fp_fwd",
            "w8a8_fwd",
            "w4a8_train_r25",
            "w8a8_train_r0",
            "w8a8_train_r100",
            "w8a8_train_lwpn",
        ] {
            let name = format!("{model}_{suffix}");
            let step = s.steps.get(&name).unwrap_or_else(|e| panic!("{name}: {e}"));
            let params = ParamStore::init(&step.manifest, 1);
            let inputs = generic_inputs(&step.manifest, &params, 7);
            let out = step.execute(&inputs).unwrap_or_else(|e| panic!("{name}: {e}"));
            if step.manifest.kind != "calib" {
                assert!(out.loss().unwrap().is_finite(), "{name}: non-finite loss");
            }
        }
    }
}

#[test]
fn execute_ws_reuse_is_bit_identical_to_fresh_execution() {
    // acceptance for the execution-plan refactor: one workspace reused
    // across models, artifact kinds, and repeated steps must never
    // change a single output bit vs the fresh-allocation path
    let s = session();
    let mut ws = efqat::exec::Workspace::new();
    for model in ["mlp", "convnet", "tiny_tf"] {
        for suffix in ["fp_train", "w8a8_fwd", "w8a8_train_r25", "w8a8_train_lwpn"] {
            let name = format!("{model}_{suffix}");
            let step = s.steps.get(&name).unwrap_or_else(|e| panic!("{name}: {e}"));
            let params = ParamStore::init(&step.manifest, 5);
            let inputs = generic_inputs(&step.manifest, &params, 23);
            let (fresh, _) = step.execute_timed(&inputs).unwrap_or_else(|e| panic!("{name}: {e}"));
            for round in 0..2 {
                let (outs, _) = step.execute_timed_ws(&inputs, &mut ws).unwrap();
                for (spec, got) in step.manifest.outputs.iter().zip(&outs) {
                    let want = fresh.get(&spec.name).unwrap();
                    assert_eq!(got.shape(), want.shape(), "{name}:{} round {round}", spec.name);
                    match (want, got) {
                        (Value::F32(a), Value::F32(b)) => {
                            assert_eq!(a.data, b.data, "{name}:{} round {round}", spec.name);
                        }
                        (Value::I32(a), Value::I32(b)) => {
                            assert_eq!(a.data, b.data, "{name}:{} round {round}", spec.name);
                        }
                        _ => panic!("{name}:{}: dtype drift", spec.name),
                    }
                }
                ws.give_values(outs);
            }
        }
    }
}

#[test]
fn partial_backward_matches_full_backward_on_unfrozen_rows() {
    // acceptance: r25 (gathered-row) gradients agree with the gathered
    // rows of the r100 (full) gradients to ≤ 1e-5, per site, for every
    // native model family — the paper's Fig. 1 (right) correctness claim
    let s = session();
    for model in ["mlp", "convnet", "tiny_tf"] {
        let full_step = s.steps.get(&format!("{model}_w8a8_train_r100")).unwrap();
        let part_step = s.steps.get(&format!("{model}_w8a8_train_r25")).unwrap();
        let params = ParamStore::init(&full_step.manifest, 3);

        // shared inputs; the partial artifact additionally binds a random
        // (but in-range) selection per site
        let full_inputs = generic_inputs(&full_step.manifest, &params, 11);
        let mut sel: std::collections::BTreeMap<String, Vec<i32>> = Default::default();
        let mut rng = Pcg64::new(42);
        let part_inputs: Vec<Value> = part_step
            .manifest
            .inputs
            .iter()
            .zip(generic_inputs(&part_step.manifest, &params, 11))
            .map(|(spec, v)| {
                if spec.role == "index" {
                    let site = spec.of.clone().unwrap();
                    let c_out = part_step
                        .manifest
                        .wsites
                        .iter()
                        .find(|w| w.name == site)
                        .unwrap()
                        .c_out;
                    let ids: Vec<i32> =
                        rng.choice(c_out, spec.shape[0]).into_iter().map(|c| c as i32).collect();
                    sel.insert(site, ids.clone());
                    Value::I32(ITensor { shape: spec.shape.clone(), data: ids })
                } else {
                    v
                }
            })
            .collect();

        let full = full_step.execute(&full_inputs).unwrap();
        let part = part_step.execute(&part_inputs).unwrap();
        assert!(
            (full.loss().unwrap() - part.loss().unwrap()).abs() < 1e-6,
            "{model}: forward loss must not depend on the selection"
        );
        for site in &full_step.manifest.wsites {
            let ids = &sel[&site.name];
            let dw_full = full.get(&format!("d:{}", site.name)).unwrap().f32().unwrap();
            let dw_part = part.get(&format!("d:{}", site.name)).unwrap().f32().unwrap();
            let rs = dw_full.data.len() / site.c_out;
            assert_eq!(dw_part.data.len(), ids.len() * rs, "{model}:{}", site.name);
            for (gi, &row) in ids.iter().enumerate() {
                let row = row as usize;
                for i in 0..rs {
                    let a = dw_full.data[row * rs + i];
                    let b = dw_part.data[gi * rs + i];
                    assert!(
                        (a - b).abs() <= 1e-5,
                        "{model}:{} row {row}[{i}]: full {a} vs partial {b}",
                        site.name
                    );
                }
            }
            let dsw_full = full.get(&format!("d:sw:{}", site.name)).unwrap().f32().unwrap();
            let dsw_part = part.get(&format!("d:sw:{}", site.name)).unwrap().f32().unwrap();
            for (gi, &row) in ids.iter().enumerate() {
                let a = dsw_full.data[row as usize];
                let b = dsw_part.data[gi];
                assert!(
                    (a - b).abs() <= 1e-5,
                    "{model}:{} dsw row {row}: full {a} vs partial {b}",
                    site.name
                );
            }
        }
    }
}

#[test]
fn convnet_partial_step_updates_only_selected_conv_channels() {
    // conv-style WSites flow through freeze.rs + the trainer exactly like
    // linear rows: frozen output channels of conv1.w must not move
    let s = session();
    let calib = s.steps.get("convnet_calib").unwrap();
    let params = ParamStore::init(&calib.manifest, 0);
    let states = StateStore::init(&calib.manifest);
    let mut task = build_task("convnet", calib.manifest.batch_size, &small_cfg()).unwrap();
    let q = calibrate(&calib, &params, &states, &mut task.calib, 128, 8, 8).unwrap();
    let step = s.steps.get("convnet_w8a8_train_r25").unwrap();
    let tcfg = TrainCfg { lr_w: 0.02, ..TrainCfg::default() };
    let mut trainer = EfqatTrainer::new(step, params, q, states, Some(Mode::Cwpl), tcfg).unwrap();

    let before = trainer.params.get("conv1.w").unwrap().clone();
    let sel = trainer.policy.as_ref().unwrap().selection().clone();
    let si = trainer.step.manifest.wsites.iter().position(|w| w.name == "conv1.w").unwrap();
    let selected = sel.channels[si].clone();
    assert_eq!(selected.len(), 2); // site_k(8, 0.25)

    task.train.reset();
    let batch = task.train.next_batch().unwrap();
    let rec = trainer.train_step(&batch).unwrap();
    assert!(rec.loss.is_finite());

    let after = trainer.params.get("conv1.w").unwrap();
    for r in 0..before.rows() {
        let changed = before.row(r) != after.row(r);
        assert_eq!(changed, selected.contains(&r), "conv channel {r}");
    }
}

#[test]
fn tiny_tf_lwpn_freezes_whole_projection_sites() {
    let s = session();
    let calib = s.steps.get("tiny_tf_calib").unwrap();
    let params = ParamStore::init(&calib.manifest, 0);
    let states = StateStore::init(&calib.manifest);
    let mut task = build_task("tiny_tf", calib.manifest.batch_size, &small_cfg()).unwrap();
    let q = calibrate(&calib, &params, &states, &mut task.calib, 64, 8, 8).unwrap();
    assert_eq!(q.sw.len(), 7, "tiny_tf has 7 freezable projection sites");
    let step = s.steps.get("tiny_tf_w8a8_train_lwpn").unwrap();
    let tcfg =
        TrainCfg { lr_w: 0.01, ratio_override: Some(0.25), ..TrainCfg::default() };
    let mut trainer = EfqatTrainer::new(step, params, q, states, Some(Mode::Lwpn), tcfg).unwrap();
    let flags = trainer.policy.as_ref().unwrap().selection().flags.clone();
    assert!(flags.iter().any(|&f| f) && flags.iter().any(|&f| !f), "budget must split sites");
    let names: Vec<String> =
        trainer.step.manifest.wsites.iter().map(|w| w.name.clone()).collect();
    let before: Vec<_> = names.iter().map(|n| trainer.params.get(n).unwrap().clone()).collect();

    task.train.reset();
    let batch = task.train.next_batch().unwrap();
    trainer.train_step(&batch).unwrap();

    for ((name, before), &flag) in names.iter().zip(&before).zip(&flags) {
        let after = trainer.params.get(name).unwrap();
        let changed = before.data != after.data;
        assert_eq!(changed, flag, "{name}: changed={changed} flag={flag}");
    }
    // embeddings never move during EfQAT (fp32, not updated)
    let emb_before = ParamStore::init(&trainer.step.manifest, 0);
    assert_eq!(
        emb_before.get("emb.tok").unwrap().data,
        trainer.params.get("emb.tok").unwrap().data
    );
}

/// Serializes the tests that flip the process-global
/// [`efqat::graph::force_backward_truncation`] override — interleaving
/// them would let one test's forced-on window corrupt the other's
/// forced-off "full backward" leg.  (Every other test in this binary is
/// truncation-invariant: with all flags high the skipped prefix holds
/// only gradient-less layers.)  Poison-recovering, like simd_parity's
/// dispatch lock.
static TRUNC: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn trunc_lock() -> std::sync::MutexGuard<'static, ()> {
    TRUNC.lock().unwrap_or_else(|e| e.into_inner())
}

/// Bitwise comparison of two named output values.
fn assert_outputs_bitwise(
    a: &efqat::backend::Outputs,
    b: &efqat::backend::Outputs,
    name: &str,
    ctx: &str,
) {
    match (a.get(name).unwrap(), b.get(name).unwrap()) {
        (Value::F32(x), Value::F32(y)) => {
            assert_eq!(x.shape, y.shape, "{ctx}:{name} shape");
            for (i, (p, q)) in x.data.iter().zip(&y.data).enumerate() {
                assert_eq!(p.to_bits(), q.to_bits(), "{ctx}:{name}[{i}]: {p} vs {q}");
            }
        }
        (Value::I32(x), Value::I32(y)) => {
            assert_eq!((&x.shape, &x.data), (&y.shape, &y.data), "{ctx}:{name}");
        }
        _ => panic!("{ctx}:{name}: dtype drift"),
    }
}

#[test]
fn truncated_backward_is_bit_identical_when_every_site_is_active() {
    // With every site active (Idx for r25, All for r100, flag=1 for
    // lwpn — generic_inputs binds flags high) the truncation boundary
    // sits at the lowest site layer, so the skipped prefix holds only
    // gradient-less layers (Flatten / quantized-step Embed).  Every
    // output must therefore be bit-identical with the truncation forced
    // off and forced on, for all three selection families.
    let _g = trunc_lock();
    let s = session();
    for model in ["mlp", "convnet", "tiny_tf"] {
        for suffix in ["w8a8_train_r25", "w8a8_train_r100", "w8a8_train_lwpn"] {
            let name = format!("{model}_{suffix}");
            let step = s.steps.get(&name).unwrap_or_else(|e| panic!("{name}: {e}"));
            let params = ParamStore::init(&step.manifest, 13);
            let inputs = generic_inputs(&step.manifest, &params, 29);
            efqat::graph::force_backward_truncation(Some(false));
            let full = step.execute(&inputs);
            efqat::graph::force_backward_truncation(Some(true));
            let trunc = step.execute(&inputs);
            efqat::graph::force_backward_truncation(None);
            let (full, trunc) = (full.unwrap(), trunc.unwrap());
            for spec in &step.manifest.outputs {
                assert_outputs_bitwise(&full, &trunc, &spec.name, &name);
            }
        }
    }
}

/// Whether a train output belongs to the frozen prefix of the LWPN
/// truncation test below (sites `frozen` plus, for tiny_tf, the `ln1`
/// norm living in the same skipped residual block as the frozen
/// attention projections).
fn below_boundary(model: &str, out: &str, frozen: &[String]) -> bool {
    for site in frozen {
        let base = site.strip_suffix(".w").unwrap_or(site);
        if out == format!("d:{site}")
            || out == format!("d:sw:{site}")
            || out == format!("d:sx:{site}")
            || out == format!("d:zx:{site}")
            || out == format!("d:{base}.b")
        {
            return true;
        }
    }
    model == "tiny_tf" && (out == "d:ln1.g" || out == "d:ln1.b")
}

#[test]
fn lwpn_frozen_prefix_truncation_zeroes_exactly_the_prefix_gradients() {
    // Freeze a leading block of sites (flags low) so the truncation
    // boundary moves up: loss/correct and every gradient at or above
    // the boundary must stay bit-identical to the untruncated backward,
    // while the frozen prefix's remaining gradients (bias / norm /
    // activation-qparam — nonzero without truncation) become the zeros
    // of the masked-update contract.
    let _g = trunc_lock();
    let s = session();
    for (model, n_frozen) in [("mlp", 1usize), ("convnet", 1), ("tiny_tf", 4)] {
        let name = format!("{model}_w8a8_train_lwpn");
        let step = s.steps.get(&name).unwrap();
        let params = ParamStore::init(&step.manifest, 3);
        let frozen: Vec<String> =
            step.manifest.wsites.iter().take(n_frozen).map(|w| w.name.clone()).collect();
        let inputs: Vec<Value> = step
            .manifest
            .inputs
            .iter()
            .zip(generic_inputs(&step.manifest, &params, 17))
            .map(|(spec, v)| {
                if spec.role == "flag" && frozen.contains(spec.of.as_ref().unwrap()) {
                    Value::I32(ITensor { shape: vec![1], data: vec![0] })
                } else {
                    v
                }
            })
            .collect();
        efqat::graph::force_backward_truncation(Some(false));
        let full = step.execute(&inputs);
        efqat::graph::force_backward_truncation(Some(true));
        let trunc = step.execute(&inputs);
        efqat::graph::force_backward_truncation(None);
        let (full, trunc) = (full.unwrap(), trunc.unwrap());
        for spec in &step.manifest.outputs {
            if below_boundary(model, &spec.name, &frozen) {
                let t = trunc.get(&spec.name).unwrap().f32().unwrap();
                assert!(
                    t.data.iter().all(|&v| v == 0.0),
                    "{name}:{}: truncated prefix grad not zeroed",
                    spec.name
                );
            } else {
                assert_outputs_bitwise(&full, &trunc, &spec.name, &name);
            }
        }
        // the truncation must be load-bearing: without it the frozen
        // site still computed a real activation-qparam gradient
        let dsx = full.get(&format!("d:sx:{}", frozen[0])).unwrap().f32().unwrap();
        assert!(
            dsx.data[0] != 0.0,
            "{name}: premise broken — full backward's prefix d:sx is already zero"
        );
    }
}

#[test]
fn native_outputs_respect_manifest_dtypes() {
    let s = session();
    let step = s.steps.get("mlp_fp_train").unwrap();
    let params = ParamStore::init(&step.manifest, 0);
    let states = StateStore::init(&step.manifest);
    let mut task = build_task("mlp", step.manifest.batch_size, &small_cfg()).unwrap();
    let batch = task.train.next_batch().unwrap();
    let ctx =
        BindCtx { params: &params, qparams: None, states: &states, batch: &batch, selection: None };
    let out = step.execute(&bind_inputs(&step.manifest, &ctx).unwrap()).unwrap();
    assert!(matches!(out.get("correct").unwrap(), Value::I32(_)));
    assert!(matches!(out.get("d:fc1.w").unwrap(), Value::F32(_)));
    assert_eq!(out.get("d:fc1.w").unwrap().shape(), &[32, 192]);
}
