//! Data-parallel training equivalence suite (ISSUE 7 acceptance).
//!
//! The contract under test: training with `W` workers is *bit-identical*
//! to training with one worker — same final weights, same quantization
//! parameters, same optimizer state, same per-step losses and metrics,
//! same eval headline — for every model and freeze ratio.  The design
//! that makes this hold (fixed virtual shards, shard-id-keyed results,
//! fixed-order tree reduction) lives in `coordinator/shard.rs`; these
//! tests are the enforcement.

use efqat::coordinator::shard::run_sharded;
use efqat::coordinator::tasks::build_task;
use efqat::coordinator::trainer::{artifact_name, DataParallelTrainer, EfqatTrainer, TrainCfg};
use efqat::coordinator::{evaluate, Session};
use efqat::freeze::Mode;
use efqat::model::{ParamStore, StateStore};
use efqat::testing::synth_qparams;

use std::path::Path;

fn session() -> Session {
    Session::new(Path::new("artifacts")).expect("native session")
}

fn small_cfg(model: &str) -> efqat::cfg::Config {
    let mut cfg = efqat::cfg::Config::empty();
    cfg.set("data.train_n", "128");
    cfg.set("data.test_n", "64");
    cfg.set("data.train_tokens", "2048");
    cfg.set("data.test_tokens", "1024");
    let _ = model;
    cfg
}

/// FNV-1a over f32 bit patterns — bit-exact, order-sensitive.
fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100000001b3);
    }
}

fn fnv_f32s(h: &mut u64, xs: &[f32]) {
    for &x in xs {
        fnv(h, &x.to_bits().to_le_bytes());
    }
}

/// Everything one training run produces, digested bit-exactly.
#[derive(Debug, PartialEq, Eq)]
struct RunDigest {
    params: u64,
    qparams: u64,
    optimizer: u64,
    losses: Vec<u32>,
    corrects: Vec<i32>,
    headline: u32,
    bytes_exchanged: u64,
    active_bytes: u64,
    dense_bytes: u64,
}

/// One EfQAT epoch of `model` at w4a8 with `workers` data-parallel
/// workers, digesting every observable output.
fn train_run(model: &str, mode_str: &str, ratio_pct: usize, workers: usize) -> RunDigest {
    let s = session();
    let art = artifact_name(model, "w4a8", mode_str, ratio_pct);
    let step = s.steps.get(&art).unwrap();
    let params = ParamStore::init(&step.manifest, 0);
    let states = StateStore::init(&step.manifest);
    let qparams = synth_qparams(&step.manifest, &params, 4, 8, 0.05);
    let mut task = build_task(model, step.manifest.batch_size, &small_cfg(model)).unwrap();
    // small freq so Top-K reselection happens mid-epoch and its input
    // (the updated weights) is part of what must stay bit-identical
    let tcfg = TrainCfg { lr_w: 0.02, freq: 64, ..TrainCfg::default() };
    let inner =
        EfqatTrainer::new(step, params, qparams, states, Mode::parse(mode_str), tcfg).unwrap();
    let mut dp = DataParallelTrainer::new(inner, workers).unwrap();
    let log = dp.train_epoch(&mut task.train).unwrap();
    let active_bytes = dp.active_bytes;
    let dense_bytes = dp.dense_bytes;
    let optimizer = dp.optimizer_digest();
    let trainer = dp.into_inner();

    let fwd = s.steps.get(&format!("{model}_w4a8_fwd")).unwrap();
    let eval =
        evaluate(&fwd, &trainer.params, Some(&trainer.qparams), &trainer.states, &mut task.test)
            .unwrap();

    let mut ph = 0xcbf29ce484222325u64;
    for (name, t) in &trainer.params.map {
        fnv(&mut ph, name.as_bytes());
        fnv_f32s(&mut ph, &t.data);
    }
    let mut qh = 0xcbf29ce484222325u64;
    for (name, t) in &trainer.qparams.sw {
        fnv(&mut qh, name.as_bytes());
        fnv_f32s(&mut qh, &t.data);
    }
    for (name, a) in &trainer.qparams.act {
        fnv(&mut qh, name.as_bytes());
        fnv_f32s(&mut qh, &[a.scale, a.zero_point]);
    }
    RunDigest {
        params: ph,
        qparams: qh,
        optimizer,
        losses: log.records.iter().map(|r| r.loss.to_bits()).collect(),
        corrects: log.records.iter().map(|r| r.correct).collect(),
        headline: eval.headline().to_bits(),
        bytes_exchanged: log.total_bytes_exchanged(),
        active_bytes,
        dense_bytes,
    }
}

fn assert_w_invariant(model: &str, mode_str: &str, ratio_pct: usize) -> RunDigest {
    let w1 = train_run(model, mode_str, ratio_pct, 1);
    assert!(!w1.losses.is_empty(), "{model} {mode_str} r{ratio_pct}: no steps ran");
    for w in [2usize, 4] {
        let ww = train_run(model, mode_str, ratio_pct, w);
        assert_eq!(w1, ww, "{model} {mode_str} r{ratio_pct}: W={w} diverged from W=1");
    }
    w1
}

#[test]
fn mlp_bit_identical_across_worker_counts() {
    let r25 = assert_w_invariant("mlp", "cwpn", 25);
    let r100 = assert_w_invariant("mlp", "qat", 100);
    // the frozen-aware exchange ships less at r=0.25 than at r=1.0
    assert!(
        r25.active_bytes < r100.active_bytes,
        "partial backward did not shrink the exchange: r25 {} vs r100 {}",
        r25.active_bytes,
        r100.active_bytes
    );
    assert!(r25.active_bytes < r25.dense_bytes, "active payload should undercut dense");
    assert_eq!(r100.active_bytes, r100.dense_bytes, "r=1.0 ships everything");
}

#[test]
fn convnet_bit_identical_across_worker_counts() {
    assert_w_invariant("convnet", "cwpn", 25);
    assert_w_invariant("convnet", "qat", 100);
}

#[test]
fn tiny_tf_bit_identical_across_worker_counts() {
    assert_w_invariant("tiny_tf", "cwpn", 25);
    assert_w_invariant("tiny_tf", "qat", 100);
}

#[test]
fn lwpn_bit_identical_and_skips_frozen_sites() {
    let d = assert_w_invariant("mlp", "lwpn", 100);
    // LWPN emits dense grads but flag-frozen sites never ship; with the
    // whole-net budget every site is unfrozen, so active == dense here
    assert_eq!(d.active_bytes, d.dense_bytes);
}

#[test]
fn cwpl_bit_identical_across_worker_counts() {
    assert_w_invariant("mlp", "cwpl", 25);
}

#[test]
fn workers_beyond_shards_clamp_and_stay_identical() {
    // 16-example batches split into 4 virtual shards; W=16 must clamp to
    // 4 workers and still produce the same bits
    let w1 = train_run("mlp", "cwpn", 25, 1);
    let w16 = train_run("mlp", "cwpn", 25, 16);
    assert_eq!(w1, w16);
}

#[test]
fn reduction_is_order_fixed_under_adversarial_completion_timing() {
    // Shard results must be keyed by shard id, not completion order:
    // earlier shards sleep longest, so with W>1 the *last* shard finishes
    // first.  Every worker count must agree with the serial W=1 run.
    let run = |workers: usize| -> Vec<f32> {
        let mut slots: Vec<usize> = (0..workers).collect();
        run_sharded(&mut slots, 4, |_slot, s| {
            std::thread::sleep(std::time::Duration::from_millis(8 * (4 - s) as u64));
            // a shard-dependent value with non-associative f32 structure
            Ok((s as f32 + 0.1) / 3.0)
        })
        .unwrap()
    };
    let serial = run(1);
    assert_eq!(run(2), serial);
    assert_eq!(run(4), serial);
}
