//! Int8 serving parity: the lowered integer engine must reproduce the
//! fake-quant float reference it was trained against.
//!
//! Acceptance (ISSUE 3): per-logit deviation ≤ 1e-3 against the `w8a8`
//! fwd artifact, *identical* eval accuracy on the mlp/convnet/tiny_tf
//! test sets, and quantize→dequantize round-trip error ≤ scale/2 per
//! element — all with real MinMax-calibrated qparams, not synthetic
//! scales.

use std::path::Path;

use efqat::backend::native::model_graph;
use efqat::backend::Value;
use efqat::cfg::Config;
use efqat::coordinator::binder::{bind_inputs, BindCtx};
use efqat::coordinator::tasks::{build_task, test_loader};
use efqat::coordinator::{calibrate, evaluate, evaluate_int8, Session};
use efqat::graph::InputKind;
use efqat::lower::{lower, lower_native, QuantizedGraph};
use efqat::model::{ParamStore, QParamStore, StateStore};
use efqat::quant::{code_asym, fq_sym};
use efqat::rng::Pcg64;
use efqat::testing::{synth_qparams, synth_row_scales};
use efqat::tensor::argmax;

const MODELS: [&str; 3] = ["mlp", "convnet", "tiny_tf"];

fn session() -> Session {
    Session::new(Path::new("artifacts")).expect("native session")
}

fn small_cfg() -> Config {
    let mut cfg = Config::empty();
    cfg.set("data.train_n", "256");
    cfg.set("data.test_n", "128");
    cfg.set("data.calib_samples", "128");
    cfg
}

/// Calibrated fixture: params + real PTQ qparams + the model's task.
fn fixture(
    s: &Session,
    model: &str,
) -> (ParamStore, StateStore, QParamStore, efqat::coordinator::tasks::Task) {
    let calib = s.steps.get(&format!("{model}_calib")).unwrap();
    let params = ParamStore::init(&calib.manifest, 0);
    let states = StateStore::init(&calib.manifest);
    let mut task = build_task(model, calib.manifest.batch_size, &small_cfg()).unwrap();
    let q = calibrate(&calib, &params, &states, &mut task.calib, 128, 8, 8).unwrap();
    (params, states, q, task)
}

#[test]
fn int8_eval_accuracy_identical_to_fakequant_eval() {
    let s = session();
    for model in MODELS {
        let (params, states, q, mut task) = fixture(&s, model);
        let fwd = s.steps.get(&format!("{model}_w8a8_fwd")).unwrap();
        let qg = lower_native(model, &params, &q, 8, 8).unwrap();

        // example-level identity: the int8 argmax must equal the float
        // argmax on every prediction whose float top-2 margin exceeds the
        // engines' per-logit agreement bound (1e-3).  A smaller margin is
        // a measurement tie — either answer is equally faithful to the
        // deployed model — and is counted instead of compared, so an
        // astronomically-unlikely near-tie cannot flake this test.
        let mut ties = 0usize;
        task.test.reset();
        while let Some(batch) = task.test.next_batch() {
            let ctx = BindCtx {
                params: &params,
                qparams: Some(&q),
                states: &states,
                batch: &batch,
                selection: None,
            };
            let out = fwd.execute(&bind_inputs(&fwd.manifest, &ctx).unwrap()).unwrap();
            let fl = out.get("logits").unwrap().f32().unwrap();
            let x = match qg.input {
                InputKind::Image { .. } => Value::F32(batch.f32s["x"].clone()),
                InputKind::Tokens { .. } => Value::I32(batch.i32s["x"].clone()),
            };
            let il = qg.forward(&x).unwrap();
            let classes = *fl.shape.last().unwrap();
            for r in 0..fl.data.len() / classes {
                let fr = &fl.data[r * classes..(r + 1) * classes];
                let ir = &il.data[r * classes..(r + 1) * classes];
                let (fa, ia) = (argmax(fr), argmax(ir));
                if fa != ia {
                    let margin = (fr[fa] - fr[ia]).abs();
                    assert!(
                        margin <= 1e-3,
                        "{model}: prediction flipped with decisive margin {margin}"
                    );
                    ties += 1;
                }
            }
        }

        // aggregate identity: with no ties (the expected case — real
        // margins are O(0.1)) the reported accuracies must be bit-equal
        let float_r = evaluate(&fwd, &params, Some(&q), &states, &mut task.test).unwrap();
        let int8_r = evaluate_int8(&qg, &mut task.test).unwrap();
        assert_eq!(float_r.n, int8_r.n, "{model}: example counts differ");
        if ties == 0 {
            assert_eq!(
                float_r.accuracy, int8_r.accuracy,
                "{model}: deployed accuracy {} != fake-quant accuracy {}",
                int8_r.accuracy, float_r.accuracy
            );
        }
        assert!(
            (float_r.loss - int8_r.loss).abs() < 1e-3,
            "{model}: loss {} vs {}",
            float_r.loss,
            int8_r.loss
        );
    }
}

#[test]
fn int8_logits_within_1e3_of_float_reference() {
    let s = session();
    for model in MODELS {
        let (params, states, q, mut task) = fixture(&s, model);
        let fwd = s.steps.get(&format!("{model}_w8a8_fwd")).unwrap();
        let qg = lower_native(model, &params, &q, 8, 8).unwrap();
        task.test.reset();
        let batch = task.test.next_batch().unwrap();
        let ctx = BindCtx {
            params: &params,
            qparams: Some(&q),
            states: &states,
            batch: &batch,
            selection: None,
        };
        let out = fwd.execute(&bind_inputs(&fwd.manifest, &ctx).unwrap()).unwrap();
        let float_logits = out.get("logits").unwrap().f32().unwrap();
        let x = match qg.input {
            InputKind::Image { .. } => Value::F32(batch.f32s["x"].clone()),
            InputKind::Tokens { .. } => Value::I32(batch.i32s["x"].clone()),
        };
        let int8_logits = qg.forward(&x).unwrap();
        assert_eq!(float_logits.shape, int8_logits.shape, "{model}");
        let mut worst = 0f32;
        for (a, b) in float_logits.data.iter().zip(&int8_logits.data) {
            worst = worst.max((a - b).abs());
        }
        assert!(worst <= 1e-3, "{model}: max per-logit deviation {worst}");
    }
}

#[test]
fn serving_batch_size_does_not_change_metrics() {
    // the engine is batch-flexible; accuracy over the same test set must
    // not depend on how it is chunked (incl. a padded final batch)
    let s = session();
    let (params, _states, q, _task) = fixture(&s, "mlp");
    let qg = lower_native("mlp", &params, &q, 8, 8).unwrap();
    let cfg = small_cfg();
    let r16 = evaluate_int8(&qg, &mut test_loader("mlp", 16, &cfg).unwrap()).unwrap();
    let r48 = evaluate_int8(&qg, &mut test_loader("mlp", 48, &cfg).unwrap()).unwrap();
    assert_eq!(r16.n, 128);
    assert_eq!(r16.n, r48.n);
    assert_eq!(r16.accuracy, r48.accuracy);
    // and the engine is fully deterministic across runs
    let again = evaluate_int8(&qg, &mut test_loader("mlp", 16, &cfg).unwrap()).unwrap();
    assert_eq!(r16.accuracy, again.accuracy);
    assert_eq!(r16.loss, again.loss);
}

#[test]
fn lowering_rejects_fp_and_unknown_models() {
    let s = session();
    let (params, _states, q, _task) = fixture(&s, "mlp");
    let err = lower_native("mlp", &params, &q, 16, 16).unwrap_err().to_string();
    assert!(err.contains("code domain"), "{err}");
    let err = lower_native("resnet8", &params, &q, 8, 8).unwrap_err().to_string();
    assert!(err.contains("native"), "{err}");
}

#[test]
fn quantize_dequantize_roundtrip_error_bounded_per_element() {
    // satellite acceptance: |v − dq(q(v))| ≤ scale/2 per element, for
    // weights under Eq. 4 per-channel scales (which cover the row max,
    // so nothing clips) and for in-range activations under Eq. 1/2
    let mut rng = Pcg64::new(5);
    for _ in 0..50 {
        let rows = 1 + rng.below(6);
        let rs = 1 + rng.below(64);
        let w = rng.normal_vec(rows * rs, 1.5);
        let sw = synth_row_scales(&w, rows, rs, 8);
        for r in 0..rows {
            for i in 0..rs {
                let v = w[r * rs + i];
                let err = (v - fq_sym(v, sw[r], 8)).abs();
                assert!(err <= 0.5 * sw[r] + 1e-6, "row {r}: err {err} scale {}", sw[r]);
            }
        }
    }
    // activations: codes round-trip within s/2 inside the clip range
    let (s, z) = (0.05f32, 128.0f32);
    for i in 0..1000 {
        let x = -6.0 + 12.0 * (i as f32 / 1000.0) * 0.98; // inside ±6.35
        let code = code_asym(x, s, z, 8);
        let back = (code as f32 - z) * s;
        assert!((x - back).abs() <= 0.5 * s + 1e-6, "x {x}: back {back}");
    }
}

#[test]
fn lowered_engine_freezes_weights_once() {
    // quantized_weights counts every i8 code exactly once per weight
    // element of every site — the deployment payload
    let (g, n_expected) = {
        let g = model_graph("convnet").unwrap();
        let n: usize = g.wsites().iter().map(|s| s.size).sum();
        (g, n)
    };
    let man = efqat::graph::build_manifest(
        &g,
        "fwd",
        &efqat::graph::StepId { kind: efqat::graph::StepKind::Fwd, w_bits: 8, a_bits: 8 },
    );
    let params = ParamStore::init(&man, 0);
    let q = synth_qparams(&man, &params, 8, 8, 0.05);
    let qg: QuantizedGraph = lower(&g, &params, &q, 8, 8).unwrap();
    assert_eq!(qg.quantized_weights(), n_expected);
}
